//! Parallel sweep driver: fan independent grid points (budgets,
//! topologies, seeds) across OS threads.
//!
//! The paper's figure harnesses evaluate many `(budget, topology)`
//! combinations; each point is an independent simulation, so the sweep is
//! embarrassingly parallel. Work is distributed by a shared atomic
//! cursor (cheap work stealing — long points don't stall short ones) and
//! results are returned **in input order**, so a parallel sweep is a
//! drop-in replacement for the serial loop.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of hardware threads (1 if unknown).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(index, point)` for every point serially. The reference path —
/// and the baseline the speedup note in `benches/engine_sweep.rs`
/// measures against.
pub fn sweep_serial<T, R, F>(points: &[T], mut f: F) -> Vec<R>
where
    F: FnMut(usize, &T) -> R,
{
    points.iter().enumerate().map(|(i, p)| f(i, p)).collect()
}

/// Run `f(index, point)` for every point on up to `threads` OS threads.
/// Results come back in input order. `f` must be `Sync` (it is shared by
/// reference across threads) and is typically a closure over read-only
/// problem data.
pub fn sweep_parallel<T, R, F>(points: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    sweep_parallel_streaming(points, threads, f, |_, _| {})
}

/// [`sweep_parallel`] with per-point streaming: `on_done(index, &result)`
/// runs on the **calling thread** as each grid point finishes, in
/// completion order — long points no longer hide the short ones until the
/// final join. The returned vector is still in input order, so this is a
/// drop-in replacement wherever ordering mattered.
pub fn sweep_parallel_streaming<T, R, F, C>(
    points: &[T],
    threads: usize,
    f: F,
    mut on_done: C,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
    C: FnMut(usize, &R),
{
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return points
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let r = f(i, p);
                on_done(i, &r);
                r
            })
            .collect();
    }

    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let cursor = &cursor;
        let f = &f;
        let (tx, rx) = std::sync::mpsc::channel::<(usize, R)>();
        for _ in 0..threads {
            let tx = tx.clone();
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, f(i, &points[i]))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        // The receiver lives on the calling thread: results stream in as
        // workers finish them, and the channel closes once every worker
        // has exited.
        for (i, r) in rx {
            on_done(i, &r);
            slots[i] = Some(r);
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("sweep point not computed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial_in_order() {
        let points: Vec<usize> = (0..53).collect();
        let f = |i: usize, &p: &usize| {
            assert_eq!(i, p);
            p * p + 1
        };
        let serial = sweep_serial(&points, f);
        for threads in [1, 2, 4, 7] {
            let par = sweep_parallel(&points, threads, f);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_point_sweeps() {
        let empty: Vec<u32> = vec![];
        assert!(sweep_parallel(&empty, 4, |_, &p| p).is_empty());
        assert_eq!(sweep_parallel(&[9u32], 4, |_, &p| p), vec![9]);
    }

    #[test]
    fn engine_runs_fan_out_deterministically() {
        // A miniature of the real use: the same engine run from several
        // threads must give the same result as serially.
        use crate::engine::{run_engine_analytic, EngineConfig};
        use crate::graph::ring;
        use crate::matching::decompose;
        use crate::rng::Rng;
        use crate::sim::{QuadraticProblem, RunConfig};
        use crate::topology::MatchaSampler;

        let g = ring(6);
        let d = decompose(&g);
        let mut prng = Rng::new(1);
        let problem = QuadraticProblem::generate(6, 8, 1.0, 0.1, &mut prng);
        let budgets = [0.25, 0.5, 0.75, 1.0];
        let run_point = |_i: usize, &cb: &f64| {
            let probs = crate::budget::optimize_activation_probabilities(&d, cb);
            let mix = crate::mixing::optimize_alpha(&d, &probs.probabilities);
            let mut sampler = MatchaSampler::new(probs.probabilities.clone(), 2);
            let cfg = EngineConfig {
                run: RunConfig {
                    lr: 0.05,
                    iterations: 80,
                    alpha: mix.alpha,
                    seed: 3,
                    ..RunConfig::default()
                },
                threads: 1,
            };
            let r = run_engine_analytic(&problem, &d.matchings, &mut sampler, &cfg);
            (r.run.total_time, r.run.final_mean)
        };
        let serial = sweep_serial(&budgets, run_point);
        let par = sweep_parallel(&budgets, 4, run_point);
        assert_eq!(par, serial);
    }

    #[test]
    fn available_threads_positive() {
        assert!(available_threads() >= 1);
    }

    #[test]
    fn streaming_callback_sees_every_point_exactly_once() {
        let points: Vec<usize> = (0..31).collect();
        for threads in [1, 4] {
            let mut seen: Vec<usize> = Vec::new();
            let results = sweep_parallel_streaming(
                &points,
                threads,
                |_i, &p| p * 2,
                |i, &r| {
                    assert_eq!(r, points[i] * 2);
                    seen.push(i);
                },
            );
            assert_eq!(results, points.iter().map(|p| p * 2).collect::<Vec<_>>());
            seen.sort_unstable();
            assert_eq!(seen, points, "threads={threads}");
        }
    }
}
