//! Parallel sweep driver: fan independent grid points (budgets,
//! topologies, seeds) across OS threads.
//!
//! The paper's figure harnesses evaluate many `(budget, topology)`
//! combinations; each point is an independent simulation, so the sweep is
//! embarrassingly parallel. Work is distributed by a shared atomic
//! cursor (cheap work stealing — long points don't stall short ones) and
//! results are returned **in input order**, so a parallel sweep is a
//! drop-in replacement for the serial loop.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of hardware threads (1 if unknown).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(index, point)` for every point serially. The reference path —
/// and the baseline the speedup note in `benches/engine_sweep.rs`
/// measures against.
pub fn sweep_serial<T, R, F>(points: &[T], mut f: F) -> Vec<R>
where
    F: FnMut(usize, &T) -> R,
{
    points.iter().enumerate().map(|(i, p)| f(i, p)).collect()
}

/// Run `f(index, point)` for every point on up to `threads` OS threads.
/// Results come back in input order. `f` must be `Sync` (it is shared by
/// reference across threads) and is typically a closure over read-only
/// problem data.
pub fn sweep_parallel<T, R, F>(points: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = points.len();
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return sweep_serial(points, f);
    }

    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let cursor = &cursor;
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i, &points[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("sweep worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("sweep point not computed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial_in_order() {
        let points: Vec<usize> = (0..53).collect();
        let f = |i: usize, &p: &usize| {
            assert_eq!(i, p);
            p * p + 1
        };
        let serial = sweep_serial(&points, f);
        for threads in [1, 2, 4, 7] {
            let par = sweep_parallel(&points, threads, f);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single_point_sweeps() {
        let empty: Vec<u32> = vec![];
        assert!(sweep_parallel(&empty, 4, |_, &p| p).is_empty());
        assert_eq!(sweep_parallel(&[9u32], 4, |_, &p| p), vec![9]);
    }

    #[test]
    fn engine_runs_fan_out_deterministically() {
        // A miniature of the real use: the same engine run from several
        // threads must give the same result as serially.
        use crate::engine::{run_engine_analytic, EngineConfig};
        use crate::graph::ring;
        use crate::matching::decompose;
        use crate::rng::Rng;
        use crate::sim::{QuadraticProblem, RunConfig};
        use crate::topology::MatchaSampler;

        let g = ring(6);
        let d = decompose(&g);
        let mut prng = Rng::new(1);
        let problem = QuadraticProblem::generate(6, 8, 1.0, 0.1, &mut prng);
        let budgets = [0.25, 0.5, 0.75, 1.0];
        let run_point = |_i: usize, &cb: &f64| {
            let probs = crate::budget::optimize_activation_probabilities(&d, cb);
            let mix = crate::mixing::optimize_alpha(&d, &probs.probabilities);
            let mut sampler = MatchaSampler::new(probs.probabilities.clone(), 2);
            let cfg = EngineConfig {
                run: RunConfig {
                    lr: 0.05,
                    iterations: 80,
                    alpha: mix.alpha,
                    seed: 3,
                    ..RunConfig::default()
                },
                threads: 1,
            };
            let r = run_engine_analytic(&problem, &d.matchings, &mut sampler, &cfg);
            (r.run.total_time, r.run.final_mean)
        };
        let serial = sweep_serial(&budgets, run_point);
        let par = sweep_parallel(&budgets, 4, run_point);
        assert_eq!(par, serial);
    }

    #[test]
    fn available_threads_positive() {
        assert!(available_threads() >= 1);
    }
}
