//! The event-driven execution engine.
//!
//! Per iteration the engine runs two phases against a discrete-event
//! queue:
//!
//! 1. **Compute phase** — every worker's local gradient step is scheduled
//!    as a `ComputeDone` event with a per-worker duration from the
//!    [`DelayPolicy`]; the phase barrier is the latest completion
//!    (stragglers stretch it).
//! 2. **Communication phase** — the activated matchings run sequentially
//!    (the paper's model); inside a matching every link is a `LinkDone`
//!    event, links running in parallel, so the matching finishes at the
//!    slowest link. Failure injection marks links dead: they charge their
//!    timeout but drop out of the mix.
//!
//! State updates go through an `Executor`: in-process (sequential
//! deterministic mode) or the bounded actor pool of [`super::actor`]
//! (logical workers sharded over [`crate::gossip::ShardedPool`] threads).
//! Both produce bit-for-bit identical trajectories, and under
//! [`AnalyticPolicy`] they reproduce [`crate::sim::run_decentralized`]
//! exactly (see `rust/tests/engine.rs`).

use super::actor::{ActorShard, MixBatch, MsgMeta, ShardCmd, ShardReply};
use super::event::{EventKind, EventQueue};
use super::policy::{AnalyticPolicy, DelayPolicy};
use crate::delay::VirtualClock;
use crate::experiment::{NoopObserver, Observer};
use crate::gossip::{shard_workers, ShardedPool};
use crate::graph::Graph;
use crate::metrics::Recorder;
use crate::sim::kernel::{
    apply_gossip, init_iterates, local_sgd_step, record_metrics, worker_streams,
};
use crate::sim::{Compression, Problem, RunConfig, RunResult};
use crate::state::{DeltaPool, StateMatrix};
use crate::topology::TopologySampler;
use crate::trace::{Counter, TraceEvent, Tracer};

/// Engine configuration: the shared run parameters plus the execution
/// mode. `threads <= 1` runs the in-process sequential mode; larger
/// values enable the bounded actor pool, which multiplexes all logical
/// workers over `min(threads, workers)` OS threads. The thread count
/// never changes results — only wall-clock.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub run: RunConfig,
    pub threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { run: RunConfig::default(), threads: 1 }
    }
}

/// Engine outcome: the standard [`RunResult`] plus engine-level
/// observability counters.
pub struct EngineResult {
    pub run: RunResult,
    /// Links dropped by failure injection over the whole run.
    pub dropped_links: usize,
    /// Discrete events processed by the queue.
    pub events: u64,
}

/// How iterate state is advanced each phase. State lives in the
/// coordinator's [`StateMatrix`] arena; executors keep it authoritative.
/// Crate-visible so the cluster backend ([`crate::cluster`]) can drive
/// the exact same iteration loop over a wire transport.
pub(crate) trait Executor {
    fn step(&mut self, k: usize, lr: f64, xs: &mut StateMatrix, tracer: &mut Tracer<'_>);
    fn mix(
        &mut self,
        k: usize,
        alpha: f64,
        matchings: &[Graph],
        activated: &[usize],
        dead: &[(usize, usize)],
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
    );

    /// Make the arena authoritative **now**: a pipelined executor (the
    /// remote coordinator of [`crate::node`]) drains every in-flight
    /// reply into `xs`. [`drive`] calls this before reading the arena
    /// for metric records, so pipelining never changes what gets
    /// recorded. Synchronous executors have nothing in flight — the
    /// default is a no-op.
    fn flush(&mut self, _xs: &mut StateMatrix, _tracer: &mut Tracer<'_>) {}

    /// An unrecoverable transport failure the executor absorbed (it
    /// cannot return errors through `step`/`mix`). [`drive`] checks this
    /// each iteration and stops replaying the schedule early; the owner
    /// of the executor surfaces the error after `drive` returns.
    fn poisoned(&self) -> bool {
        false
    }
}

/// Route each live activated edge of a round to both of its endpoints,
/// in global (activation, edge) order — the fold order every worker
/// shares with the sequential kernel. `per` is the reusable per-worker
/// route list (cleared here). One copy serves both the actor executor
/// and the cluster executor ([`crate::cluster`]): their bit-for-bit
/// parity rides on routing identically.
pub(crate) fn route_per_worker(
    per: &mut [Vec<(usize, usize, usize)>],
    matchings: &[Graph],
    activated: &[usize],
    dead: &[(usize, usize)],
) {
    for routes in per.iter_mut() {
        routes.clear();
    }
    for &j in activated {
        for &(u, v) in matchings[j].edges() {
            if dead.contains(&(u, v)) {
                continue;
            }
            per[u].push((j, u, v));
            per[v].push((j, u, v));
        }
    }
}

/// Stage one shard's gossip messages for a round: walk the shard's
/// workers in slot order, and for each routed edge push its metadata
/// (via `make`) and copy the peer's post-step row into the flat staging
/// buffer. The other half of the staging-order contract next to
/// [`route_per_worker`] — the actor executor (`MsgMeta` batches) and the
/// cluster executor (`WireMeta` frames, [`crate::cluster`]) must stage
/// identically, so both call this.
///
/// With `suppress_local` set, a peer row whose worker lives on the
/// receiving shard (round-robin assignment: worker `w` lives on shard
/// `w % shards`) is **not staged at all** — the wire executors ship
/// [`crate::cluster::wire::WireMsg::MixLocal`] frames whose receiver
/// resolves such rows from its own pre-mix segment, so the row's bytes
/// never cross the transport. Metadata is always pushed for every
/// message; `intra_rows` counts the suppressible rows either way (the
/// savings accounting of `LinkStats::intra_bytes`). The in-process actor
/// executor stages everything (`suppress_local = false`): its batches
/// never touch a wire.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stage_shard_messages<M>(
    shard: usize,
    shards: usize,
    workers: usize,
    per: &[Vec<(usize, usize, usize)>],
    xs: &StateMatrix,
    msgs: &mut Vec<M>,
    staging: &mut Vec<f64>,
    intra_rows: &mut u64,
    suppress_local: bool,
    make: impl Fn(usize, usize, usize, usize) -> M,
) {
    msgs.clear();
    staging.clear();
    for (slot, w) in shard_workers(shard, shards, workers).enumerate() {
        for &(j, u, v) in &per[w] {
            let peer = if w == u { v } else { u };
            let local = peer % shards == shard;
            if local {
                *intra_rows += 1;
            }
            msgs.push(make(slot, j, u, v));
            if !(suppress_local && local) {
                staging.extend_from_slice(xs.row(peer));
            }
        }
    }
}

/// In-process executor: the shared kernel, worker loop in index order.
struct SequentialExec<'p, P: Problem + ?Sized> {
    problem: &'p P,
    worker_rngs: Vec<crate::rng::Rng>,
    pool: DeltaPool,
    compression: Option<Compression>,
    seed: u64,
}

impl<P: Problem + ?Sized> Executor for SequentialExec<'_, P> {
    fn step(&mut self, _k: usize, lr: f64, xs: &mut StateMatrix, _tracer: &mut Tracer<'_>) {
        for w in 0..xs.rows() {
            local_sgd_step(
                self.problem,
                w,
                lr,
                xs.row_mut(w),
                &mut self.worker_rngs[w],
                self.pool.grad_mut(),
            );
        }
    }

    fn mix(
        &mut self,
        k: usize,
        alpha: f64,
        matchings: &[Graph],
        activated: &[usize],
        dead: &[(usize, usize)],
        xs: &mut StateMatrix,
        _tracer: &mut Tracer<'_>,
    ) {
        apply_gossip(
            xs,
            matchings,
            activated,
            alpha,
            self.compression.as_ref(),
            Some(dead),
            self.seed,
            k,
            &mut self.pool,
        );
    }
}

/// Actor-pool executor: broadcasts phase commands to every shard,
/// gathers replies, and keeps the coordinator's arena authoritative for
/// routing. All per-iteration buffers — the per-worker message lists,
/// each shard's [`MixBatch`] (message metadata + staged peer rows) and
/// state-return buffer — are allocated once and recycled through the
/// command/reply cycle, so the mix path performs no per-message heap
/// allocation.
struct ActorExec<'a> {
    pool: &'a ShardedPool<ShardCmd, ShardReply>,
    workers: usize,
    /// Per-worker `(matching, u, v)` routes for the current round, in
    /// global (activation, edge) order; reused across iterations.
    per: Vec<Vec<(usize, usize, usize)>>,
    /// Recycled per-shard mix batches.
    batches: Vec<Option<MixBatch>>,
    /// Recycled per-shard state-return buffers.
    rets: Vec<Option<Vec<f64>>>,
}

impl<'a> ActorExec<'a> {
    fn new(pool: &'a ShardedPool<ShardCmd, ShardReply>, workers: usize) -> Self {
        let shards = pool.num_shards();
        ActorExec {
            pool,
            workers,
            per: (0..workers).map(|_| Vec::new()).collect(),
            batches: (0..shards).map(|_| Some(MixBatch::default())).collect(),
            rets: (0..shards).map(|_| Some(Vec::new())).collect(),
        }
    }

    /// Receive every shard's reply, copy its segment back into the
    /// arena, reclaim the recycled buffers, and fold the shard-side
    /// work counters into the run's metric registry.
    fn collect(&mut self, xs: &mut StateMatrix, tracer: &mut Tracer<'_>) {
        let shards = self.pool.num_shards();
        let d = xs.dim();
        for _ in 0..shards {
            let reply = self.pool.recv();
            let s = reply.shard;
            for (slot, w) in shard_workers(s, shards, self.workers).enumerate() {
                xs.row_mut(w).copy_from_slice(&reply.states[slot * d..(slot + 1) * d]);
            }
            tracer.count(Counter::ShardSteps, reply.steps);
            tracer.count(Counter::ShardMsgsFolded, reply.folded);
            self.rets[s] = Some(reply.states);
            if let Some(batch) = reply.batch {
                self.batches[s] = Some(batch);
            }
        }
    }
}

impl Executor for ActorExec<'_> {
    fn step(&mut self, _k: usize, lr: f64, xs: &mut StateMatrix, tracer: &mut Tracer<'_>) {
        for s in 0..self.pool.num_shards() {
            let ret = self.rets[s].take().expect("return buffer leased out");
            self.pool.send(s, ShardCmd::Step { lr, ret });
        }
        self.collect(xs, tracer);
    }

    fn mix(
        &mut self,
        k: usize,
        alpha: f64,
        matchings: &[Graph],
        activated: &[usize],
        dead: &[(usize, usize)],
        xs: &mut StateMatrix,
        tracer: &mut Tracer<'_>,
    ) {
        route_per_worker(&mut self.per, matchings, activated, dead);
        // Stage each shard's batch: messages in slot order, each peer's
        // post-step row copied from the arena into the flat staging
        // buffer at the message's index.
        let shards = self.pool.num_shards();
        for s in 0..shards {
            let mut batch = self.batches[s].take().expect("mix batch leased out");
            stage_shard_messages(
                s,
                shards,
                self.workers,
                &self.per,
                xs,
                &mut batch.msgs,
                &mut batch.staging,
                &mut 0, // in-process: the intra/remote split is wire-only
                false, // stage everything — these batches never touch a wire
                |slot, j, u, v| MsgMeta { slot, matching: j, u, v },
            );
            let ret = self.rets[s].take().expect("return buffer leased out");
            self.pool.send(s, ShardCmd::Mix { k, alpha, batch, ret });
        }
        self.collect(xs, tracer);
    }
}

/// Run the engine. Dispatches on `config.threads`: sequential in-process
/// mode (`<= 1`) or the bounded actor pool (`min(threads, workers)` OS
/// threads, any number of workers).
pub fn run_engine<P, S>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &EngineConfig,
) -> EngineResult
where
    P: Problem + Sync,
    S: TopologySampler,
{
    run_engine_observed(problem, matchings, sampler, policy, config, &mut NoopObserver)
}

/// [`run_engine`] with streaming observation: `observer` receives a
/// callback (on the driving thread, even in actor mode) after every
/// iteration and at every metrics record. The trajectory is identical to
/// the unobserved run.
pub fn run_engine_observed<P, S>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &EngineConfig,
    observer: &mut dyn Observer,
) -> EngineResult
where
    P: Problem + Sync,
    S: TopologySampler,
{
    run_engine_traced(
        problem,
        matchings,
        sampler,
        policy,
        config,
        observer,
        &mut Tracer::disabled(),
    )
}

/// [`run_engine_observed`] with trace emission: compute/link spans,
/// mix/barrier markers and run counters flow through `tracer`. With a
/// disabled tracer this **is** the observed run — the trajectory never
/// depends on tracing.
pub fn run_engine_traced<P, S>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &EngineConfig,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> EngineResult
where
    P: Problem + Sync,
    S: TopologySampler,
{
    let m = problem.num_workers();
    let d = problem.dim();
    if config.threads <= 1 {
        let exec = SequentialExec {
            problem,
            worker_rngs: worker_streams(config.run.seed, m),
            pool: DeltaPool::new(m, d),
            compression: config.run.compression.clone(),
            seed: config.run.seed,
        };
        return drive(problem, matchings, sampler, policy, &config.run, exec, observer, tracer);
    }

    let threads = config.threads.min(m);
    let xs0 = init_iterates(config.run.seed, m, d);
    let rngs = worker_streams(config.run.seed, m);
    std::thread::scope(|scope| {
        let shards: Vec<ActorShard<'_, P>> = (0..threads)
            .map(|s| {
                ActorShard::for_partition(
                    problem,
                    config.run.compression.clone(),
                    config.run.seed,
                    s,
                    threads,
                    &xs0,
                    &rngs,
                )
            })
            .collect();
        let pool = ShardedPool::spawn(scope, shards, |shard: &mut ActorShard<'_, P>, cmd| {
            shard.handle(cmd)
        });
        let exec = ActorExec::new(&pool, m);
        let result =
            drive(problem, matchings, sampler, policy, &config.run, exec, observer, tracer);
        drop(pool);
        result
    })
}

/// Convenience: run with the analytic policy matching `config.run` — the
/// mode that reproduces [`crate::sim::run_decentralized`] bit-for-bit.
pub fn run_engine_analytic<P, S>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    config: &EngineConfig,
) -> EngineResult
where
    P: Problem + Sync,
    S: TopologySampler,
{
    let mut policy = AnalyticPolicy::matching_run_config(&config.run);
    run_engine(problem, matchings, sampler, &mut policy, config)
}

/// The shared event-driven iteration loop. Crate-visible so every
/// barrier backend — in-process, actor pool, and the transport-separated
/// cluster ([`crate::cluster::run_cluster`]) — runs the one loop and
/// shares its time accounting bit-for-bit.
pub(crate) fn drive<P, S, E>(
    problem: &P,
    matchings: &[Graph],
    sampler: &mut S,
    policy: &mut dyn DelayPolicy,
    config: &RunConfig,
    mut exec: E,
    observer: &mut dyn Observer,
    tracer: &mut Tracer<'_>,
) -> EngineResult
where
    P: Problem + ?Sized,
    S: TopologySampler,
    E: Executor,
{
    let m = problem.num_workers();
    let d = problem.dim();
    let mut xs = init_iterates(config.seed, m, d);
    let mut queue = EventQueue::new();
    let mut clock = VirtualClock::new(config.compute_units);
    let mut metrics = Recorder::new();
    let mut total_comm = 0.0;
    let mut dropped = 0usize;
    let mut lr = config.lr;

    if let Some(w) = record_metrics(problem, 0, 0.0, 0.0, &xs, &mut metrics, tracer) {
        observer.on_window(&w);
    }
    observer.on_record(0, 0.0, &metrics);

    for k in 0..config.iterations {
        if exec.poisoned() {
            // The executor hit an unrecoverable transport failure:
            // replaying more schedule would only queue commands into a
            // dead link. Its owner reports the error after drive returns.
            break;
        }
        let t0 = clock.elapsed();

        // --- compute phase (barrier at the slowest worker) -----------
        let mut compute_dur = 0.0f64;
        for w in 0..m {
            let ct = policy.compute_time(w, k);
            tracer.emit_at(t0, TraceEvent::ComputeBegin { worker: w, k });
            tracer.observatory.on_compute(w, ct);
            queue.schedule(t0 + ct, EventKind::ComputeDone { worker: w, k });
            compute_dur = compute_dur.max(ct);
        }
        // Drain the phase barrier explicitly so each completion is
        // traced at its own event time (in deterministic (time, seq)
        // pop order).
        while let Some(ev) = queue.pop() {
            if let EventKind::ComputeDone { worker, k: ek } = ev.kind {
                tracer.emit_at(ev.time, TraceEvent::ComputeEnd { worker, k: ek });
                tracer.count(Counter::ComputeEvents, 1);
            }
        }
        tracer.set_now(t0 + compute_dur);
        exec.step(k, lr, &mut xs, tracer);

        // --- communication phase -------------------------------------
        let round = sampler.round(k);
        let mut dead: Vec<(usize, usize)> = Vec::new();
        let mut comm_t = match policy.analytic_comm_time(matchings, &round.activated) {
            Some(t) => t,
            None => {
                // Matchings serialize; links inside a matching run in
                // parallel. Durations accumulate per matching (rather
                // than differencing absolute event times) to stay
                // bit-exact with the closed-form path.
                let mut total = 0.0f64;
                let mut t_matching = t0 + compute_dur;
                for &j in &round.activated {
                    let mut dur = 0.0f64;
                    for &(u, v) in matchings[j].edges() {
                        let failed = policy.link_fails(u, v, k);
                        let lt = policy.link_time(j, u, v, k);
                        tracer.emit_at(t_matching, TraceEvent::LinkBegin { matching: j, u, v, k });
                        // Event times carry the *unscaled* link duration;
                        // the compression time factor below applies to the
                        // iteration total only. If event timestamps ever
                        // become authoritative (async mode), scale here.
                        queue.schedule(
                            t_matching + lt,
                            EventKind::LinkDone { matching: j, edge: (u, v), k, failed },
                        );
                        if failed {
                            dead.push((u, v));
                        }
                        dur = dur.max(lt);
                    }
                    while let Some(ev) = queue.pop() {
                        if let EventKind::LinkDone { matching, edge: (u, v), k: ek, failed } =
                            ev.kind
                        {
                            tracer.emit_at(
                                ev.time,
                                TraceEvent::LinkEnd { matching, u, v, k: ek, failed },
                            );
                            tracer.count(Counter::LinkEvents, 1);
                        }
                    }
                    t_matching += dur;
                    total += dur;
                }
                total
            }
        };
        if let Some(comp) = &config.compression {
            comm_t *= comp.time_factor(config.latency_floor);
        }
        dropped += dead.len();
        tracer.count(Counter::DroppedLinks, dead.len() as u64);
        tracer.observatory.on_round(&round.activated, &dead);

        // --- mix phase -----------------------------------------------
        tracer.set_now(t0 + compute_dur + comm_t);
        if !round.activated.is_empty() {
            exec.mix(k, config.alpha, matchings, &round.activated, &dead, &mut xs, tracer);
        }

        // --- time accounting & recording -----------------------------
        total_comm += comm_t;
        let now = clock.advance(compute_dur + comm_t);
        tracer.set_now(now);
        tracer.emit(TraceEvent::MixApplied { k, activated: round.activated.len() });
        tracer.emit(TraceEvent::RoundBarrier { k });
        tracer.count(Counter::MixRounds, 1);
        if (k + 1) % config.lr_decay_every == 0 {
            lr *= config.lr_decay;
        }
        if (k + 1) % config.record_every == 0 || k + 1 == config.iterations {
            // A pipelined executor may still have replies in flight;
            // records must read the same arena a synchronous run would.
            exec.flush(&mut xs, tracer);
            if let Some(w) =
                record_metrics(problem, k + 1, now, total_comm, &xs, &mut metrics, tracer)
            {
                observer.on_window(&w);
            }
            observer.on_record(k + 1, now, &metrics);
        }
        observer.on_iteration(k + 1, now, total_comm);
    }
    exec.flush(&mut xs, tracer);

    EngineResult {
        run: RunResult {
            final_mean: xs.mean(),
            final_states: xs,
            total_time: clock.elapsed(),
            total_comm_units: total_comm,
            metrics,
        },
        dropped_links: dropped,
        events: queue.processed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::optimize_activation_probabilities;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;
    use crate::mixing::optimize_alpha;
    use crate::rng::Rng;
    use crate::sim::QuadraticProblem;
    use crate::topology::{MatchaSampler, VanillaSampler};

    fn quad(m: usize) -> QuadraticProblem {
        let mut rng = Rng::new(99);
        QuadraticProblem::generate(m, 10, 1.0, 0.1, &mut rng)
    }

    #[test]
    fn sequential_engine_matches_sim_runner_exactly() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.5);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let p = quad(8);
        let cfg = RunConfig {
            lr: 0.02,
            iterations: 300,
            alpha: mix.alpha,
            seed: 12,
            ..RunConfig::default()
        };

        let mut s1 = MatchaSampler::new(probs.probabilities.clone(), 4);
        let reference = crate::sim::run_decentralized(&p, &d.matchings, &mut s1, &cfg);

        let mut s2 = MatchaSampler::new(probs.probabilities.clone(), 4);
        let engine = run_engine_analytic(
            &p,
            &d.matchings,
            &mut s2,
            &EngineConfig { run: cfg, threads: 1 },
        );

        assert_eq!(engine.run.final_mean, reference.final_mean);
        assert_eq!(engine.run.total_time, reference.total_time);
        assert_eq!(engine.run.total_comm_units, reference.total_comm_units);
        assert_eq!(engine.dropped_links, 0);
        assert!(engine.events > 0, "event queue must actually be exercised");
    }

    #[test]
    fn parallel_actors_match_sequential_engine_exactly() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let probs = optimize_activation_probabilities(&d, 0.4);
        let mix = optimize_alpha(&d, &probs.probabilities);
        let p = quad(8);
        let cfg = RunConfig {
            lr: 0.03,
            iterations: 120,
            alpha: mix.alpha,
            seed: 31,
            ..RunConfig::default()
        };

        let mut s1 = MatchaSampler::new(probs.probabilities.clone(), 6);
        let seq = run_engine_analytic(
            &p,
            &d.matchings,
            &mut s1,
            &EngineConfig { run: cfg.clone(), threads: 1 },
        );
        let mut s2 = MatchaSampler::new(probs.probabilities.clone(), 6);
        let par = run_engine_analytic(
            &p,
            &d.matchings,
            &mut s2,
            &EngineConfig { run: cfg, threads: 8 },
        );
        assert_eq!(par.run.final_mean, seq.run.final_mean);
        assert_eq!(par.run.total_time, seq.run.total_time);
    }

    #[test]
    fn bounded_pool_multiplexes_more_workers_than_threads() {
        // 300 workers on a 3-thread pool — beyond the old 256-worker
        // one-thread-per-worker cap — must still match the sequential
        // executor bit-for-bit.
        let g = crate::graph::ring(300);
        let d = decompose(&g);
        let p = quad(300);
        let cfg =
            RunConfig { lr: 0.03, iterations: 8, alpha: 0.2, seed: 2, ..RunConfig::default() };
        let mut s1 = VanillaSampler::new(d.len());
        let seq = run_engine_analytic(
            &p,
            &d.matchings,
            &mut s1,
            &EngineConfig { run: cfg.clone(), threads: 1 },
        );
        let mut s2 = VanillaSampler::new(d.len());
        let par = run_engine_analytic(
            &p,
            &d.matchings,
            &mut s2,
            &EngineConfig { run: cfg, threads: 3 },
        );
        assert_eq!(par.run.final_mean, seq.run.final_mean);
        assert_eq!(par.run.total_time, seq.run.total_time);
    }

    #[test]
    fn straggler_stretches_iteration_time_exactly() {
        use super::super::policy::StragglerPolicy;
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let p = quad(8);
        let iters = 50usize;
        let cfg = RunConfig {
            iterations: iters,
            alpha: 0.1,
            seed: 7,
            ..RunConfig::default()
        };
        let engine_cfg = EngineConfig { run: cfg.clone(), threads: 1 };
        let factor = 4.0;

        let mut s1 = VanillaSampler::new(d.len());
        let base = run_engine_analytic(&p, &d.matchings, &mut s1, &engine_cfg);

        let mut s2 = VanillaSampler::new(d.len());
        let mut policy = StragglerPolicy::new(
            AnalyticPolicy::matching_run_config(&cfg),
            vec![3],
            factor,
        );
        let straggled = run_engine(&p, &d.matchings, &mut s2, &mut policy, &engine_cfg);

        // Vanilla activates every matching every iteration: per-iteration
        // time is compute + M without the straggler, factor·compute + M
        // with it (compute_units = 1).
        let m_count = d.len() as f64;
        assert_eq!(base.run.total_time, iters as f64 * (1.0 + m_count));
        assert_eq!(
            straggled.run.total_time,
            iters as f64 * (factor + m_count),
            "one straggler must gate every iteration's compute phase"
        );
        // The trajectory itself is unaffected — only time stretches.
        assert_eq!(straggled.run.final_mean, base.run.final_mean);
    }

    #[test]
    fn flaky_links_drop_but_preserve_worker_mean_dynamics() {
        use super::super::policy::FlakyLinkPolicy;
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let p = quad(8);
        let cfg = RunConfig {
            lr: 0.02,
            iterations: 400,
            alpha: 0.15,
            seed: 3,
            ..RunConfig::default()
        };
        let engine_cfg = EngineConfig { run: cfg.clone(), threads: 1 };
        let mut sampler = VanillaSampler::new(d.len());
        let mut policy =
            FlakyLinkPolicy::new(AnalyticPolicy::matching_run_config(&cfg), 0.3, 11);
        let res = run_engine(&p, &d.matchings, &mut sampler, &mut policy, &engine_cfg);
        assert!(res.dropped_links > 0, "failure injection must trigger");
        // Still converges: dropped links only slow consensus.
        let sub0 = res.run.metrics.get("subopt_vs_iter")[0].y;
        let subf = res.run.metrics.last("subopt_vs_iter").unwrap();
        assert!(subf < 0.2 * sub0, "no convergence under flaky links: {sub0} -> {subf}");
    }

    #[test]
    fn hetero_policy_changes_time_not_trajectory() {
        use super::super::policy::HeterogeneousPolicy;
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let p = quad(8);
        let cfg = RunConfig { iterations: 60, alpha: 0.1, seed: 5, ..RunConfig::default() };
        let engine_cfg = EngineConfig { run: cfg.clone(), threads: 1 };

        let mut s1 = VanillaSampler::new(d.len());
        let base = run_engine_analytic(&p, &d.matchings, &mut s1, &engine_cfg);
        let mut s2 = VanillaSampler::new(d.len());
        let mut policy = HeterogeneousPolicy::generate(&g, 1.0, 42);
        let het = run_engine(&p, &d.matchings, &mut s2, &mut policy, &engine_cfg);

        assert_eq!(het.run.final_mean, base.run.final_mean);
        assert_ne!(het.run.total_time, base.run.total_time);
    }
}
