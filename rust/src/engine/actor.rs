//! Worker actors for the engine's parallel execution mode.
//!
//! Each worker is an actor on its own `std::thread`, owning its iterate
//! and its private gradient-noise RNG stream, and exchanging messages
//! with the coordinator over `mpsc` channels:
//!
//! ```text
//!   coordinator ── Cmd::Step ──▶ worker     (local SGD step)
//!   coordinator ◀─ Reply::Stepped ── worker (post-step iterate)
//!   coordinator ── Cmd::Mix ───▶ worker     (peer iterates for its
//!                                            activated incident links)
//!   coordinator ◀─ Reply::Mixed ─── worker  (post-mix iterate)
//! ```
//!
//! Determinism: a worker's gradient draws depend only on its own stream,
//! and gossip-message compression randomness is derived per edge
//! ([`crate::sim::kernel::edge_rng`]), so the result is bit-for-bit
//! identical to the sequential path regardless of thread scheduling. The
//! coordinator's per-iteration barrier (collect all `Stepped`, then all
//! `Mixed`) is what the ISSUE calls deterministic mode.

use crate::rng::Rng;
use crate::sim::kernel::{edge_diff_message, local_sgd_step};
use crate::sim::{Compression, Problem};
use std::sync::mpsc::{Receiver, Sender};

/// One gossip message routed to a worker: the peer's post-step iterate
/// for one activated, live link. `(u, v)` is the canonical edge (u < v);
/// the receiving worker is one of the two endpoints.
pub(crate) struct GossipMsg {
    pub matching: usize,
    pub u: usize,
    pub v: usize,
    pub peer_x: Vec<f64>,
}

/// Coordinator → worker commands.
pub(crate) enum Cmd {
    /// Run one local SGD step at learning rate `lr`. (The iteration
    /// index is not needed worker-side: gradient draws come from the
    /// worker's own stream; only `Mix` needs `k`, for the per-edge
    /// compression RNG.)
    Step { lr: f64 },
    /// Apply the gossip mix for iteration `k`. `msgs` lists this worker's
    /// live activated incident links in global (activation, edge) order —
    /// possibly empty, in which case the mix is a no-op add of zero
    /// (matching the sequential kernel exactly).
    Mix { k: usize, alpha: f64, msgs: Vec<GossipMsg> },
    /// Shut down the actor.
    Stop,
}

/// Worker → coordinator replies (carrying the worker's current iterate so
/// the coordinator's mirror stays authoritative for routing/metrics).
pub(crate) enum Reply {
    Stepped { worker: usize, x: Vec<f64> },
    Mixed { worker: usize, x: Vec<f64> },
}

/// The actor body. Runs until `Cmd::Stop` or a closed channel.
pub(crate) fn worker_loop<P: Problem + ?Sized>(
    problem: &P,
    worker: usize,
    mut x: Vec<f64>,
    mut rng: Rng,
    compression: Option<Compression>,
    seed: u64,
    rx: Receiver<Cmd>,
    tx: Sender<Reply>,
) {
    let d = x.len();
    let mut grad = vec![0.0; d];
    let mut diff = vec![0.0; d];
    let mut delta = vec![0.0; d];
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Step { lr } => {
                local_sgd_step(problem, worker, lr, &mut x, &mut rng, &mut grad);
                if tx.send(Reply::Stepped { worker, x: x.clone() }).is_err() {
                    return;
                }
            }
            Cmd::Mix { k, alpha, msgs } => {
                delta.iter_mut().for_each(|v| *v = 0.0);
                for msg in &msgs {
                    // Canonical message diff = x_v − x_u; this worker is
                    // the u side iff worker == msg.u.
                    let on_lower = worker == msg.u;
                    if on_lower {
                        edge_diff_message(
                            &x,
                            &msg.peer_x,
                            &mut diff,
                            compression.as_ref(),
                            seed,
                            k,
                            msg.matching,
                            msg.u,
                            msg.v,
                        );
                        for i in 0..d {
                            delta[i] += diff[i];
                        }
                    } else {
                        edge_diff_message(
                            &msg.peer_x,
                            &x,
                            &mut diff,
                            compression.as_ref(),
                            seed,
                            k,
                            msg.matching,
                            msg.u,
                            msg.v,
                        );
                        for i in 0..d {
                            delta[i] -= diff[i];
                        }
                    }
                }
                for i in 0..d {
                    x[i] += alpha * delta[i];
                }
                if tx.send(Reply::Mixed { worker, x: x.clone() }).is_err() {
                    return;
                }
            }
            Cmd::Stop => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::{init_iterates, worker_streams};
    use crate::sim::QuadraticProblem;
    use std::sync::mpsc;

    #[test]
    fn actor_step_matches_inprocess_kernel() {
        let mut prng = Rng::new(17);
        let problem = QuadraticProblem::generate(3, 6, 1.0, 0.2, &mut prng);
        let seed = 5u64;
        let xs = init_iterates(seed, 3, 6);
        let rngs = worker_streams(seed, 3);

        // Reference: in-process kernel step for worker 1.
        let mut x_ref = xs[1].clone();
        let mut rng_ref = rngs[1].clone();
        let mut grad = vec![0.0; 6];
        local_sgd_step(&problem, 1, 0.03, &mut x_ref, &mut rng_ref, &mut grad);

        // Actor path.
        std::thread::scope(|scope| {
            let (cmd_tx, cmd_rx) = mpsc::channel();
            let (reply_tx, reply_rx) = mpsc::channel();
            let x0 = xs[1].clone();
            let rng = rngs[1].clone();
            let p = &problem;
            scope.spawn(move || worker_loop(p, 1, x0, rng, None, seed, cmd_rx, reply_tx));
            cmd_tx.send(Cmd::Step { lr: 0.03 }).unwrap();
            match reply_rx.recv().unwrap() {
                Reply::Stepped { worker, x } => {
                    assert_eq!(worker, 1);
                    assert_eq!(x, x_ref, "actor step must be bit-identical");
                }
                _ => panic!("expected Stepped"),
            }
            cmd_tx.send(Cmd::Stop).unwrap();
        });
    }

    #[test]
    fn actor_mix_empty_message_list_applies_zero_delta() {
        let mut prng = Rng::new(23);
        let problem = QuadraticProblem::generate(2, 4, 1.0, 0.0, &mut prng);
        let x0 = vec![1.0, -2.0, 3.0, 0.5];
        std::thread::scope(|scope| {
            let (cmd_tx, cmd_rx) = mpsc::channel();
            let (reply_tx, reply_rx) = mpsc::channel();
            let p = &problem;
            let x = x0.clone();
            scope.spawn(move || worker_loop(p, 0, x, Rng::new(1), None, 0, cmd_rx, reply_tx));
            cmd_tx
                .send(Cmd::Mix { k: 0, alpha: 0.4, msgs: vec![] })
                .unwrap();
            match reply_rx.recv().unwrap() {
                Reply::Mixed { x, .. } => assert_eq!(x, x0),
                _ => panic!("expected Mixed"),
            }
            cmd_tx.send(Cmd::Stop).unwrap();
        });
    }
}
