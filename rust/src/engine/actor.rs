//! Worker shards for the engine's parallel execution mode.
//!
//! The actor mode multiplexes all logical workers over a **bounded pool**
//! of OS threads ([`crate::gossip::ShardedPool`] — shared with the
//! asynchronous gossip runtime). Each shard thread owns the sticky state
//! (iterate + private gradient-noise RNG stream) of the workers assigned
//! to it round-robin, and the coordinator drives the pool with
//! phase-broadcast commands:
//!
//! ```text
//!   coordinator ── ShardCmd::Step ──▶ shard   (local SGD step, every
//!                                              owned worker)
//!   coordinator ◀─ ShardReply ─────── shard   (post-step iterates)
//!   coordinator ── ShardCmd::Mix ───▶ shard   (peer iterates for each
//!                                              owned worker's activated
//!                                              incident links)
//!   coordinator ◀─ ShardReply ─────── shard   (post-mix iterates)
//! ```
//!
//! Determinism: a worker's gradient draws depend only on its own stream,
//! and gossip-message compression randomness is derived per edge
//! ([`crate::sim::kernel::edge_rng`]), so the result is bit-for-bit
//! identical to the sequential path regardless of thread scheduling or
//! pool size. The coordinator's per-iteration barrier (collect every
//! shard's `Step` reply, then every `Mix` reply) is what makes this the
//! engine's deterministic mode. There is no worker cap: 10k workers run
//! fine on 8 threads.

use crate::rng::Rng;
use crate::sim::kernel::{edge_diff_message, local_sgd_step};
use crate::sim::{Compression, Problem};

/// One gossip message routed to a worker: the peer's post-step iterate
/// for one activated, live link. `(u, v)` is the canonical edge (u < v);
/// the receiving worker is one of the two endpoints.
pub(crate) struct GossipMsg {
    pub matching: usize,
    pub u: usize,
    pub v: usize,
    pub peer_x: Vec<f64>,
}

/// Coordinator → shard commands. Each command covers **all** workers the
/// shard owns and yields exactly one [`ShardReply`].
pub(crate) enum ShardCmd {
    /// Run one local SGD step at learning rate `lr` on every owned
    /// worker. (The iteration index is not needed worker-side: gradient
    /// draws come from each worker's own stream; only `Mix` needs `k`,
    /// for the per-edge compression RNG.)
    Step { lr: f64 },
    /// Apply the gossip mix for iteration `k`. `msgs[i]` lists the live
    /// activated incident links of the shard's `i`-th owned worker in
    /// global (activation, edge) order — possibly empty, in which case
    /// that worker's mix is a no-op add of zero (matching the sequential
    /// kernel exactly).
    Mix { k: usize, alpha: f64, msgs: Vec<Vec<GossipMsg>> },
}

/// Shard → coordinator reply: the post-phase iterate of every owned
/// worker, so the coordinator's mirror stays authoritative for routing
/// and metrics.
pub(crate) struct ShardReply {
    pub states: Vec<(usize, Vec<f64>)>,
}

/// Sticky per-worker state owned by a shard thread.
pub(crate) struct WorkerSlot {
    pub worker: usize,
    pub x: Vec<f64>,
    pub rng: Rng,
}

/// One shard of the bounded actor pool: a bundle of workers multiplexed
/// on one OS thread, plus the shared scratch buffers.
pub(crate) struct ActorShard<'p, P: Problem + ?Sized> {
    problem: &'p P,
    compression: Option<Compression>,
    seed: u64,
    slots: Vec<WorkerSlot>,
    grad: Vec<f64>,
    diff: Vec<f64>,
    delta: Vec<f64>,
}

impl<'p, P: Problem + ?Sized> ActorShard<'p, P> {
    pub fn new(
        problem: &'p P,
        compression: Option<Compression>,
        seed: u64,
        slots: Vec<WorkerSlot>,
    ) -> Self {
        let d = problem.dim();
        ActorShard {
            problem,
            compression,
            seed,
            slots,
            grad: vec![0.0; d],
            diff: vec![0.0; d],
            delta: vec![0.0; d],
        }
    }

    /// Handle one phase command for every owned worker and report the
    /// resulting iterates.
    pub fn handle(&mut self, cmd: ShardCmd) -> ShardReply {
        match cmd {
            ShardCmd::Step { lr } => {
                for slot in self.slots.iter_mut() {
                    local_sgd_step(
                        self.problem,
                        slot.worker,
                        lr,
                        &mut slot.x,
                        &mut slot.rng,
                        &mut self.grad,
                    );
                }
            }
            ShardCmd::Mix { k, alpha, msgs } => {
                assert_eq!(msgs.len(), self.slots.len(), "one message list per owned worker");
                for (slot, worker_msgs) in self.slots.iter_mut().zip(&msgs) {
                    mix_worker(
                        slot.worker,
                        &mut slot.x,
                        worker_msgs,
                        k,
                        alpha,
                        self.compression.as_ref(),
                        self.seed,
                        &mut self.diff,
                        &mut self.delta,
                    );
                }
            }
        }
        ShardReply {
            states: self.slots.iter().map(|s| (s.worker, s.x.clone())).collect(),
        }
    }
}

/// Apply one worker's gossip mix from its routed peer messages: fold the
/// canonical edge diffs (x_v − x_u, this worker on the `u` side iff
/// `worker == msg.u`) into a delta in message order, then apply
/// `x += α·Δ` — the same accumulation the sequential kernel performs.
pub(crate) fn mix_worker(
    worker: usize,
    x: &mut [f64],
    msgs: &[GossipMsg],
    k: usize,
    alpha: f64,
    compression: Option<&Compression>,
    seed: u64,
    diff: &mut [f64],
    delta: &mut [f64],
) {
    let d = x.len();
    delta.iter_mut().for_each(|v| *v = 0.0);
    for msg in msgs {
        let on_lower = worker == msg.u;
        if on_lower {
            edge_diff_message(
                x,
                &msg.peer_x,
                diff,
                compression,
                seed,
                k,
                msg.matching,
                msg.u,
                msg.v,
            );
            for i in 0..d {
                delta[i] += diff[i];
            }
        } else {
            edge_diff_message(
                &msg.peer_x,
                x,
                diff,
                compression,
                seed,
                k,
                msg.matching,
                msg.u,
                msg.v,
            );
            for i in 0..d {
                delta[i] -= diff[i];
            }
        }
    }
    for i in 0..d {
        x[i] += alpha * delta[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::{init_iterates, worker_streams};
    use crate::sim::QuadraticProblem;

    #[test]
    fn shard_step_matches_inprocess_kernel() {
        let mut prng = Rng::new(17);
        let problem = QuadraticProblem::generate(3, 6, 1.0, 0.2, &mut prng);
        let seed = 5u64;
        let xs = init_iterates(seed, 3, 6);
        let rngs = worker_streams(seed, 3);

        // Reference: in-process kernel step for workers 1 and 2.
        let mut expect = Vec::new();
        for w in [1usize, 2] {
            let mut x_ref = xs[w].clone();
            let mut rng_ref = rngs[w].clone();
            let mut grad = vec![0.0; 6];
            local_sgd_step(&problem, w, 0.03, &mut x_ref, &mut rng_ref, &mut grad);
            expect.push((w, x_ref));
        }

        // Shard path: one shard owning workers 1 and 2.
        let slots = [1usize, 2]
            .iter()
            .map(|&w| WorkerSlot { worker: w, x: xs[w].clone(), rng: rngs[w].clone() })
            .collect();
        let mut shard = ActorShard::new(&problem, None, seed, slots);
        let reply = shard.handle(ShardCmd::Step { lr: 0.03 });
        assert_eq!(reply.states, expect, "shard step must be bit-identical");
    }

    #[test]
    fn shard_mix_empty_message_list_applies_zero_delta() {
        let mut prng = Rng::new(23);
        let problem = QuadraticProblem::generate(2, 4, 1.0, 0.0, &mut prng);
        let x0 = vec![1.0, -2.0, 3.0, 0.5];
        let slots = vec![WorkerSlot { worker: 0, x: x0.clone(), rng: Rng::new(1) }];
        let mut shard = ActorShard::new(&problem, None, 0, slots);
        let reply = shard.handle(ShardCmd::Mix { k: 0, alpha: 0.4, msgs: vec![vec![]] });
        assert_eq!(reply.states, vec![(0, x0)]);
    }

    #[test]
    fn mix_worker_matches_sequential_gossip_kernel() {
        use crate::sim::kernel::{apply_gossip, GossipScratch};
        let g = crate::graph::paper_figure1_graph();
        let d = crate::matching::decompose(&g);
        let m = 8;
        let dim = 5;
        let mut rng = Rng::new(4);
        let xs: Vec<Vec<f64>> = (0..m)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();
        let activated: Vec<usize> = (0..d.len()).collect();
        let (alpha, k, seed) = (0.21, 3, 9);

        // Reference: the full-state simultaneous kernel.
        let mut reference = xs.clone();
        let mut scratch = GossipScratch::new(m, dim);
        apply_gossip(
            &mut reference,
            &d.matchings,
            &activated,
            alpha,
            None,
            None,
            seed,
            k,
            &mut scratch,
        );

        // Per-worker path: route each worker's incident messages in
        // global order and fold them with mix_worker.
        for w in 0..m {
            let mut msgs = Vec::new();
            for &j in &activated {
                for &(u, v) in d.matchings[j].edges() {
                    if u == w {
                        msgs.push(GossipMsg { matching: j, u, v, peer_x: xs[v].clone() });
                    } else if v == w {
                        msgs.push(GossipMsg { matching: j, u, v, peer_x: xs[u].clone() });
                    }
                }
            }
            let mut x = xs[w].clone();
            let mut diff = vec![0.0; dim];
            let mut delta = vec![0.0; dim];
            mix_worker(w, &mut x, &msgs, k, alpha, None, seed, &mut diff, &mut delta);
            assert_eq!(x, reference[w], "worker {w} diverged from the kernel");
        }
    }
}
