//! Worker shards for the engine's parallel execution mode.
//!
//! The actor mode multiplexes all logical workers over a **bounded pool**
//! of OS threads ([`crate::gossip::ShardedPool`] — shared with the
//! asynchronous gossip runtime). Each shard thread owns the sticky state
//! of the workers assigned to it round-robin — their iterates live in a
//! private [`StateMatrix`] **arena segment**, one row per owned worker,
//! next to their private gradient-noise RNG streams — and the coordinator
//! drives the pool with phase-broadcast commands:
//!
//! ```text
//!   coordinator ── ShardCmd::Step ──▶ shard   (local SGD step, every
//!                                              owned worker)
//!   coordinator ◀─ ShardReply ─────── shard   (post-step iterates, one
//!                                              flat buffer)
//!   coordinator ── ShardCmd::Mix ───▶ shard   (one MixBatch: message
//!                                              metadata + staged peer
//!                                              rows for every owned
//!                                              worker's activated links)
//!   coordinator ◀─ ShardReply ─────── shard   (post-mix iterates + the
//!                                              batch, returned for reuse)
//! ```
//!
//! **Zero per-message allocation**: gossip messages are `(slot, matching,
//! u, v)` metadata plus the peer row staged into the batch's flat
//! `staging` buffer — never a cloned `Vec<f64>` per message. The staging
//! buffers, message vectors and state-return buffers shuttle between
//! coordinator and shard inside the commands/replies, so after the first
//! iteration the steady state allocates nothing in the mix path (measured
//! in `benches/hotpath.rs` → `BENCH_state.json`).
//!
//! Determinism: a worker's gradient draws depend only on its own stream,
//! and gossip-message compression randomness is derived per edge
//! ([`crate::sim::kernel::edge_rng`]), so the result is bit-for-bit
//! identical to the sequential path regardless of thread scheduling or
//! pool size. The coordinator's per-iteration barrier (collect every
//! shard's `Step` reply, then every `Mix` reply) is what makes this the
//! engine's deterministic mode. There is no worker cap: 10k workers run
//! fine on 8 threads.

use crate::cluster::wire::{MixLocalRef, WireError};
use crate::gossip::shard_workers;
use crate::rng::Rng;
use crate::sim::kernel::local_sgd_step;
use crate::sim::{Compression, Problem};
use crate::state::{MixKernel, RowSource, StateMatrix};

/// One gossip message routed to a worker: the metadata of one activated,
/// live link. `(u, v)` is the canonical edge (u < v); the receiving
/// worker (`slot`-th owned worker of its shard) is one of the two
/// endpoints. The peer's post-step row is staged at the message's index
/// in the enclosing [`MixBatch::staging`] buffer.
pub(crate) struct MsgMeta {
    pub slot: usize,
    pub matching: usize,
    pub u: usize,
    pub v: usize,
}

/// One shard's gossip traffic for one iteration: message metadata sorted
/// by owner slot (global (activation, edge) order within each slot) and
/// the matching peer rows, message `i`'s peer at `staging[i*d..(i+1)*d]`.
/// Round-trips coordinator → shard → coordinator so both vectors keep
/// their capacity across iterations.
#[derive(Default)]
pub(crate) struct MixBatch {
    pub msgs: Vec<MsgMeta>,
    pub staging: Vec<f64>,
}

/// Coordinator → shard commands. Each command covers **all** workers the
/// shard owns and yields exactly one [`ShardReply`]. `ret` is the
/// recycled flat buffer the shard fills with its post-phase iterates.
pub(crate) enum ShardCmd {
    /// Run one local SGD step at learning rate `lr` on every owned
    /// worker. (The iteration index is not needed worker-side: gradient
    /// draws come from each worker's own stream; only `Mix` needs `k`,
    /// for the per-edge compression RNG.)
    Step { lr: f64, ret: Vec<f64> },
    /// Apply the gossip mix for iteration `k`. Workers without messages
    /// in the batch get a no-op add of zero (matching the sequential
    /// kernel exactly).
    Mix { k: usize, alpha: f64, batch: MixBatch, ret: Vec<f64> },
}

/// Shard → coordinator reply: the post-phase iterates of every owned
/// worker (slot order, flat `slots × d`), so the coordinator's arena
/// stays authoritative for routing and metrics. `batch` returns the mix
/// buffers for reuse (`None` after a step). `steps` / `folded` report
/// the shard-side work done by the phase (SGD steps run, gossip
/// messages folded) for the run's metric registry.
pub(crate) struct ShardReply {
    pub shard: usize,
    pub states: Vec<f64>,
    pub batch: Option<MixBatch>,
    pub steps: u64,
    pub folded: u64,
}

/// One shard of the bounded actor pool: a bundle of workers multiplexed
/// on one OS thread. Worker `workers[slot]`'s iterate is row `slot` of
/// the `seg` arena segment; `rngs[slot]` is its gradient stream.
pub(crate) struct ActorShard<'p, P: Problem + ?Sized> {
    problem: &'p P,
    compression: Option<Compression>,
    seed: u64,
    shard: usize,
    workers: Vec<usize>,
    seg: StateMatrix,
    rngs: Vec<Rng>,
    grad: Vec<f64>,
    diff: Vec<f64>,
    delta: Vec<f64>,
    /// Recycled TopK compression scratch
    /// ([`crate::sim::Compression::compress_with`]).
    comp: Vec<f64>,
    /// Pre-mix snapshot of the segment, taken at the top of
    /// [`ActorShard::mix_from_frame`]: suppressed local-peer rows must
    /// read post-step iterates even after earlier slots have mixed.
    snap: StateMatrix,
}

impl<'p, P: Problem + ?Sized> ActorShard<'p, P> {
    pub fn new(
        problem: &'p P,
        compression: Option<Compression>,
        seed: u64,
        shard: usize,
        workers: Vec<usize>,
        seg: StateMatrix,
        rngs: Vec<Rng>,
    ) -> Self {
        assert_eq!(workers.len(), seg.rows(), "one segment row per owned worker");
        assert_eq!(workers.len(), rngs.len(), "one RNG stream per owned worker");
        let d = problem.dim();
        let snap = StateMatrix::zeros(workers.len(), d);
        ActorShard {
            problem,
            compression,
            seed,
            shard,
            workers,
            seg,
            rngs,
            grad: vec![0.0; d],
            diff: vec![0.0; d],
            delta: vec![0.0; d],
            comp: Vec::with_capacity(d),
            snap,
        }
    }

    /// Build the shard owning partition `shard` of `shards` over the
    /// workers of `xs0`: the slot-ordered worker list from the shared
    /// round-robin assignment, a per-shard arena segment copied out of
    /// `xs0`, and the owned workers' RNG streams cloned from `rngs`.
    /// The single construction path the actor pool and the cluster
    /// driver ([`crate::cluster`]) share — bit-for-bit parity between
    /// them rides on building shards identically.
    pub fn for_partition(
        problem: &'p P,
        compression: Option<Compression>,
        seed: u64,
        shard: usize,
        shards: usize,
        xs0: &StateMatrix,
        rngs: &[Rng],
    ) -> Self {
        let workers: Vec<usize> = shard_workers(shard, shards, xs0.rows()).collect();
        let mut seg = StateMatrix::zeros(workers.len(), xs0.dim());
        for (slot, &w) in workers.iter().enumerate() {
            seg.row_mut(slot).copy_from_slice(xs0.row(w));
        }
        let shard_rngs = workers.iter().map(|&w| rngs[w].clone()).collect();
        ActorShard::new(problem, compression, seed, shard, workers, seg, shard_rngs)
    }

    /// The shard's current iterates (slot order, flat `slots × dim`) —
    /// exactly what a [`ShardReply`] carries. The shard-node daemon
    /// ([`crate::node`]) sends this in its `Resume` handshake frame so a
    /// reconnecting coordinator can re-synchronize its arena with work
    /// whose replies were lost with the previous connection.
    pub fn states(&self) -> &[f64] {
        self.seg.as_slice()
    }

    /// Copy the segment into the recycled return buffer.
    fn states_into(&self, mut ret: Vec<f64>) -> Vec<f64> {
        ret.clear();
        ret.extend_from_slice(self.seg.as_slice());
        ret
    }

    /// Handle one phase command for every owned worker and report the
    /// resulting iterates.
    pub fn handle(&mut self, cmd: ShardCmd) -> ShardReply {
        match cmd {
            ShardCmd::Step { lr, ret } => {
                for (slot, &w) in self.workers.iter().enumerate() {
                    local_sgd_step(
                        self.problem,
                        w,
                        lr,
                        self.seg.row_mut(slot),
                        &mut self.rngs[slot],
                        &mut self.grad,
                    );
                }
                ShardReply {
                    shard: self.shard,
                    states: self.states_into(ret),
                    batch: None,
                    steps: self.workers.len() as u64,
                    folded: 0,
                }
            }
            ShardCmd::Mix { k, alpha, batch, ret } => {
                let d = self.seg.dim();
                let kernel = MixKernel::new(self.seed, self.compression.as_ref());
                let mut i = 0usize;
                for (slot, &w) in self.workers.iter().enumerate() {
                    let start = i;
                    while i < batch.msgs.len() && batch.msgs[i].slot == slot {
                        i += 1;
                    }
                    // Every owned worker folds — an empty message run is
                    // the sequential kernel's `x += α·0` on non-incident
                    // workers of an active round.
                    let msgs = batch.msgs[start..i].iter().enumerate().map(|(o, m)| {
                        let at = (start + o) * d;
                        (m.matching, m.u, m.v, RowSource::Host(&batch.staging[at..at + d]))
                    });
                    kernel.fold_worker(
                        w,
                        self.seg.row_mut(slot),
                        msgs,
                        k,
                        alpha,
                        &mut self.diff,
                        &mut self.delta,
                        &mut self.comp,
                    );
                }
                assert_eq!(
                    i,
                    batch.msgs.len(),
                    "mix batch not consumed: messages must be sorted by owner slot"
                );
                let folded = batch.msgs.len() as u64;
                ShardReply {
                    shard: self.shard,
                    states: self.states_into(ret),
                    batch: Some(batch),
                    steps: 0,
                    folded,
                }
            }
        }
    }

    /// Apply a gossip mix streamed straight out of a received wire frame
    /// ([`MixLocalRef`]), the zero-copy twin of `ShardCmd::Mix`:
    ///
    /// - **Shipped peer rows** fold as [`RowSource::Wire`] — little-endian
    ///   byte slices borrowed from the frame body, never copied into host
    ///   staging first.
    /// - **Suppressed local-peer rows** (both endpoints on this shard; the
    ///   coordinator omits them from the frame) resolve from a pre-mix
    ///   snapshot of this shard's own segment — exactly the post-step
    ///   iterates the coordinator would have staged, since its arena and
    ///   this segment agree at mix time.
    ///
    /// Message order and arithmetic are identical to the staged-batch
    /// path, so the result is bit-for-bit the same iterates.
    pub fn mix_from_frame(
        &mut self,
        frame: &MixLocalRef<'_>,
        ret: Vec<f64>,
    ) -> Result<ShardReply, WireError> {
        let d = self.seg.dim();
        if frame.dim as usize != d || frame.shard as usize != self.shard {
            return Err(WireError::Inconsistent(format!(
                "mix-local frame for shard {} dim {} reached shard {} dim {}",
                frame.shard, frame.dim, self.shard, d
            )));
        }
        let shards = frame.shards as usize;
        let (k, alpha) = (frame.k as usize, frame.alpha);
        // The fold mutates the segment slot by slot, but a suppressed
        // message must read the peer's *post-step* iterate — snapshot
        // the whole segment before any slot moves.
        self.snap.as_mut_slice().copy_from_slice(self.seg.as_slice());
        let kernel = MixKernel::new(self.seed, self.compression.as_ref());
        let mut msgs = frame.msgs();
        let mut pending = msgs.next();
        let mut folded = 0u64;
        for (slot, &w) in self.workers.iter().enumerate() {
            self.delta.iter_mut().for_each(|v| *v = 0.0);
            while let Some((meta, row)) = pending {
                if meta.slot as usize != slot {
                    break;
                }
                let (j, u, v) = (meta.matching as usize, meta.u as usize, meta.v as usize);
                let peer = if w == u { v } else { u };
                let src = match row {
                    Some(bytes) => RowSource::Wire(bytes),
                    None => {
                        // Round-robin assignment puts worker `peer` at
                        // slot `peer / shards` of its shard; anything
                        // else means the frame lied about locality.
                        let ps = peer / shards;
                        if self.workers.get(ps) != Some(&peer) {
                            return Err(WireError::Inconsistent(format!(
                                "suppressed peer {peer} of message ({u},{v}) \
                                 is not owned by shard {}",
                                self.shard
                            )));
                        }
                        RowSource::Host(self.snap.row(ps))
                    }
                };
                kernel.fold_msg(
                    w,
                    self.snap.row(slot),
                    j,
                    u,
                    v,
                    src,
                    k,
                    &mut self.diff,
                    &mut self.delta,
                    &mut self.comp,
                );
                folded += 1;
                pending = msgs.next();
            }
            MixKernel::apply_delta(self.seg.row_mut(slot), alpha, &self.delta);
        }
        if pending.is_some() {
            return Err(WireError::Inconsistent(
                "mix-local messages not sorted by owner slot".into(),
            ));
        }
        Ok(ShardReply {
            shard: self.shard,
            states: self.states_into(ret),
            batch: None,
            steps: 0,
            folded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::{init_iterates, worker_streams};
    use crate::sim::QuadraticProblem;

    fn shard_for<'p>(
        problem: &'p QuadraticProblem,
        seed: u64,
        workers: Vec<usize>,
        xs: &StateMatrix,
        rngs: &[Rng],
    ) -> ActorShard<'p, QuadraticProblem> {
        let mut seg = StateMatrix::zeros(workers.len(), xs.dim());
        for (slot, &w) in workers.iter().enumerate() {
            seg.row_mut(slot).copy_from_slice(xs.row(w));
        }
        let shard_rngs = workers.iter().map(|&w| rngs[w].clone()).collect();
        ActorShard::new(problem, None, seed, 0, workers, seg, shard_rngs)
    }

    #[test]
    fn shard_step_matches_inprocess_kernel() {
        let mut prng = Rng::new(17);
        let problem = QuadraticProblem::generate(3, 6, 1.0, 0.2, &mut prng);
        let seed = 5u64;
        let xs = init_iterates(seed, 3, 6);
        let rngs = worker_streams(seed, 3);

        // Reference: in-process kernel step for workers 1 and 2.
        let mut expect = Vec::new();
        for w in [1usize, 2] {
            let mut x_ref = xs.row(w).to_vec();
            let mut rng_ref = rngs[w].clone();
            let mut grad = vec![0.0; 6];
            local_sgd_step(&problem, w, 0.03, &mut x_ref, &mut rng_ref, &mut grad);
            expect.extend_from_slice(&x_ref);
        }

        // Shard path: one shard owning workers 1 and 2.
        let mut shard = shard_for(&problem, seed, vec![1, 2], &xs, &rngs);
        let reply = shard.handle(ShardCmd::Step { lr: 0.03, ret: Vec::new() });
        assert_eq!(reply.states, expect, "shard step must be bit-identical");
        assert_eq!(reply.shard, 0);
        assert!(reply.batch.is_none());
    }

    #[test]
    fn shard_mix_without_messages_applies_zero_delta() {
        let mut prng = Rng::new(23);
        let problem = QuadraticProblem::generate(2, 4, 1.0, 0.0, &mut prng);
        let x0 = vec![1.0, -2.0, 3.0, 0.5];
        let xs = StateMatrix::from_vecs(&[x0.clone(), vec![0.0; 4]]);
        let rngs = worker_streams(0, 2);
        let mut shard = shard_for(&problem, 0, vec![0], &xs, &rngs);
        let reply = shard.handle(ShardCmd::Mix {
            k: 0,
            alpha: 0.4,
            batch: MixBatch::default(),
            ret: Vec::new(),
        });
        assert_eq!(reply.states, x0);
        let batch = reply.batch.expect("mix returns its batch for reuse");
        assert!(batch.msgs.is_empty() && batch.staging.is_empty());
    }

    #[test]
    fn shard_mix_matches_sequential_gossip_kernel() {
        use crate::sim::kernel::apply_gossip;
        use crate::state::DeltaPool;
        let g = crate::graph::paper_figure1_graph();
        let d = crate::matching::decompose(&g);
        let m = 8;
        let dim = 5;
        let mut rng = Rng::new(4);
        let mut xs = StateMatrix::zeros(m, dim);
        for w in 0..m {
            for x in xs.row_mut(w).iter_mut() {
                *x = rng.normal();
            }
        }
        let activated: Vec<usize> = (0..d.len()).collect();
        let (alpha, k, seed) = (0.21, 3, 9);
        let mut rng2 = Rng::new(1);
        let problem = QuadraticProblem::generate(m, dim, 1.0, 0.0, &mut rng2);

        // Reference: the full-state simultaneous kernel.
        let mut reference = xs.clone();
        let mut pool = DeltaPool::new(m, dim);
        apply_gossip(
            &mut reference,
            &d.matchings,
            &activated,
            alpha,
            None,
            None,
            seed,
            k,
            &mut pool,
        );

        // Shard path: one shard owning all workers, messages staged in
        // slot order with global (activation, edge) order within a slot.
        let rngs = worker_streams(seed, m);
        let workers: Vec<usize> = (0..m).collect();
        let mut batch = MixBatch::default();
        for (slot, &w) in workers.iter().enumerate() {
            for &j in &activated {
                for &(u, v) in d.matchings[j].edges() {
                    if u == w || v == w {
                        let peer = if u == w { v } else { u };
                        batch.msgs.push(MsgMeta { slot, matching: j, u, v });
                        batch.staging.extend_from_slice(xs.row(peer));
                    }
                }
            }
        }
        let mut shard = shard_for(&problem, seed, workers, &xs, &rngs);
        let reply = shard.handle(ShardCmd::Mix { k, alpha, batch, ret: Vec::new() });
        assert_eq!(reply.states, reference.as_slice(), "shard mix diverged from the kernel");
    }

    #[test]
    fn mix_from_frame_matches_staged_batch_bit_for_bit() {
        use crate::cluster::wire::{WireMeta, WireMsg, FRAME_HEADER_BYTES};
        let g = crate::graph::paper_figure1_graph();
        let d = crate::matching::decompose(&g);
        let (m, dim, shards, shard_id) = (8usize, 5usize, 2usize, 0usize);
        let (alpha, k, seed) = (0.21f64, 3usize, 9u64);
        let compression = Some(crate::sim::Compression::TopK { frac: 0.6 });
        let mut rng = Rng::new(4);
        let mut xs = StateMatrix::zeros(m, dim);
        for w in 0..m {
            for x in xs.row_mut(w).iter_mut() {
                *x = rng.normal();
            }
        }
        let activated: Vec<usize> = (0..d.len()).collect();
        let mut rng2 = Rng::new(1);
        let problem = QuadraticProblem::generate(m, dim, 1.0, 0.0, &mut rng2);
        let rngs = worker_streams(seed, m);

        // Shard 0 of 2 owns workers 0, 2, 4, 6. Build the staged batch
        // (every peer row shipped) and the suppressed wire frame (only
        // odd — remote — peers shipped) over the same message order.
        let workers: Vec<usize> = shard_workers(shard_id, shards, m).collect();
        let mut batch = MixBatch::default();
        let mut metas: Vec<WireMeta> = Vec::new();
        let mut staging: Vec<f64> = Vec::new();
        for (slot, &w) in workers.iter().enumerate() {
            for &j in &activated {
                for &(u, v) in d.matchings[j].edges() {
                    if u == w || v == w {
                        let peer = if u == w { v } else { u };
                        batch.msgs.push(MsgMeta { slot, matching: j, u, v });
                        batch.staging.extend_from_slice(xs.row(peer));
                        metas.push(WireMeta {
                            slot: slot as u32,
                            matching: j as u32,
                            u: u as u32,
                            v: v as u32,
                        });
                        if peer % shards != shard_id {
                            staging.extend_from_slice(xs.row(peer));
                        }
                    }
                }
            }
        }
        assert!(staging.len() < batch.staging.len(), "some rows must be suppressed");
        assert!(!staging.is_empty(), "some rows must still ship");

        let build = |xs: &StateMatrix| {
            let mut seg = StateMatrix::zeros(workers.len(), dim);
            for (slot, &w) in workers.iter().enumerate() {
                seg.row_mut(slot).copy_from_slice(xs.row(w));
            }
            let shard_rngs = workers.iter().map(|&w| rngs[w].clone()).collect();
            ActorShard::new(
                &problem,
                compression.clone(),
                seed,
                shard_id,
                workers.clone(),
                seg,
                shard_rngs,
            )
        };

        let mut staged = build(&xs);
        let staged_reply = staged.handle(ShardCmd::Mix { k, alpha, batch, ret: Vec::new() });

        let mut frame = Vec::new();
        WireMsg::MixLocal {
            k: k as u64,
            alpha,
            shard: shard_id as u32,
            shards: shards as u32,
            dim: dim as u32,
            msgs: metas,
            staging,
        }
        .encode(&mut frame);
        let view = crate::cluster::wire::MixLocalRef::decode(&frame[FRAME_HEADER_BYTES..])
            .expect("frame decodes");
        assert!(view.suppressed() > 0);
        let mut zero_copy = build(&xs);
        let frame_reply = zero_copy.mix_from_frame(&view, Vec::new()).expect("frame mix");

        assert_eq!(frame_reply.folded, staged_reply.folded);
        for (a, b) in frame_reply.states.iter().zip(&staged_reply.states) {
            assert_eq!(a.to_bits(), b.to_bits(), "frame mix diverged from staged mix");
        }
    }

    #[test]
    fn mix_from_frame_rejects_misaddressed_frames() {
        use crate::cluster::wire::{MixLocalRef, WireMsg, FRAME_HEADER_BYTES};
        let mut prng = Rng::new(23);
        let problem = QuadraticProblem::generate(2, 4, 1.0, 0.0, &mut prng);
        let xs = init_iterates(0, 2, 4);
        let rngs = worker_streams(0, 2);
        let mut shard = shard_for(&problem, 0, vec![0], &xs, &rngs);
        // Wrong dim (3 ≠ 4) for an otherwise well-formed frame.
        let mut frame = Vec::new();
        WireMsg::MixLocal {
            k: 0,
            alpha: 0.4,
            shard: 0,
            shards: 2,
            dim: 3,
            msgs: vec![],
            staging: vec![],
        }
        .encode(&mut frame);
        let view = MixLocalRef::decode(&frame[FRAME_HEADER_BYTES..]).unwrap();
        assert!(shard.mix_from_frame(&view, Vec::new()).is_err());
        // A suppressed peer this shard does not own.
        let mut frame = Vec::new();
        WireMsg::MixLocal {
            k: 0,
            alpha: 0.4,
            shard: 0,
            shards: 2,
            dim: 4,
            msgs: vec![crate::cluster::wire::WireMeta { slot: 0, matching: 0, u: 0, v: 2 }],
            staging: vec![],
        }
        .encode(&mut frame);
        let view = MixLocalRef::decode(&frame[FRAME_HEADER_BYTES..]).unwrap();
        assert!(shard.mix_from_frame(&view, Vec::new()).is_err());
    }
}
