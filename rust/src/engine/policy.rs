//! Delay policies: per-link / per-worker time models for the engine.
//!
//! [`crate::delay::DelayModel`] charges a closed-form communication time
//! per iteration. The engine generalizes it to a [`DelayPolicy`] that
//! yields durations at *event granularity* — one per local compute step
//! and one per link transmission — which is what lets the engine express
//! the scenarios the analytic model cannot: heterogeneous links,
//! stragglers, and link failures. The analytic model survives as one
//! policy among several ([`AnalyticPolicy`]), with exact time parity to
//! the sequential simulator.

use crate::delay::DelayModel;
use crate::graph::Graph;
use crate::rng::Rng;

/// A time model at per-event granularity.
///
/// All methods take `&mut self` because stochastic policies consume RNG
/// state; the engine guarantees a deterministic call order (workers in
/// index order for compute, activated matchings in activation order and
/// edges in storage order for links), so policy draws are reproducible.
pub trait DelayPolicy: Send {
    /// Duration of worker `w`'s local gradient step at iteration `k`.
    fn compute_time(&mut self, w: usize, k: usize) -> f64;

    /// Transmission duration of link `(u, v)` of matching `j` at
    /// iteration `k`.
    fn link_time(&mut self, j: usize, u: usize, v: usize, k: usize) -> f64;

    /// Does link `(u, v)` fail at iteration `k`? A failed link still
    /// charges its [`DelayPolicy::link_time`] (detection timeout) but is
    /// dropped from the mix. Default: never.
    fn link_fails(&mut self, _u: usize, _v: usize, _k: usize) -> bool {
        false
    }

    /// Closed-form override: when `Some`, the engine charges this for the
    /// whole iteration's communication instead of simulating link events.
    /// Only [`AnalyticPolicy`] uses it (for [`DelayModel::MaxDegree`],
    /// which models a *non-decomposed* execution and has no per-matching
    /// link schedule). Default: `None`.
    fn analytic_comm_time(&mut self, _matchings: &[Graph], _activated: &[usize]) -> Option<f64> {
        None
    }

    /// Human-readable policy name for logs/benches.
    fn name(&self) -> &'static str;
}

/// The sequential simulator's time model, at event granularity where
/// possible. Constructed via [`AnalyticPolicy::matching_run_config`] it
/// reproduces [`crate::sim::run_decentralized`]'s clock exactly:
///
/// - `UnitPerMatching`: every link takes 1 unit, so a matching (links in
///   parallel) takes 1 unit and an iteration's communication is the
///   activated count — identical to the closed form.
/// - `StochasticLink`: link draws come from the same RNG stream in the
///   same order as [`DelayModel::comm_time`], and the engine sums
///   per-matching maxima in activation order, so the totals agree
///   bit-for-bit.
/// - `MaxDegree`: charged via the closed-form override (it models the
///   naive non-decomposed schedule, which has no link-level timeline).
pub struct AnalyticPolicy {
    model: DelayModel,
    compute_units: f64,
    rng: Rng,
}

impl AnalyticPolicy {
    pub fn new(model: DelayModel, compute_units: f64, rng: Rng) -> Self {
        AnalyticPolicy { model, compute_units, rng }
    }

    /// The policy matching a [`crate::sim::RunConfig`]'s clock: same
    /// delay model, same compute units, same delay RNG stream.
    pub fn matching_run_config(config: &crate::sim::RunConfig) -> Self {
        Self::new(config.delay.clone(), config.compute_units, config.delay_rng())
    }
}

impl DelayPolicy for AnalyticPolicy {
    fn compute_time(&mut self, _w: usize, _k: usize) -> f64 {
        self.compute_units
    }

    fn link_time(&mut self, _j: usize, _u: usize, _v: usize, _k: usize) -> f64 {
        match self.model {
            DelayModel::UnitPerMatching => 1.0,
            DelayModel::StochasticLink { min_units, max_units } => {
                self.rng.uniform_in(min_units, max_units)
            }
            // Only reachable if a wrapper suppresses the closed-form
            // override below; wrappers here all forward it, and
            // `parse_policy` rejects the one combination (flaky over
            // maxdeg) that would have to suppress it.
            DelayModel::MaxDegree => 1.0,
        }
    }

    fn analytic_comm_time(&mut self, matchings: &[Graph], activated: &[usize]) -> Option<f64> {
        match self.model {
            DelayModel::MaxDegree => {
                Some(self.model.comm_time(matchings, activated, &mut self.rng))
            }
            _ => None,
        }
    }

    fn name(&self) -> &'static str {
        "analytic"
    }
}

/// Heterogeneous cluster: per-worker compute speeds and per-link
/// bandwidths, fixed for the whole run (drawn once from a seed).
pub struct HeterogeneousPolicy {
    /// Compute duration per worker.
    compute: Vec<f64>,
    /// Link duration per base-graph edge, keyed by canonical `(u, v)`.
    link: std::collections::BTreeMap<(usize, usize), f64>,
    /// Fallback for links not in the map (e.g. freshly added edges).
    default_link: f64,
}

impl HeterogeneousPolicy {
    /// Draw per-worker compute in `[0.5, 1.5)·compute_units` and per-link
    /// time in `[0.5, 2.0)` units from `seed`.
    pub fn generate(base: &Graph, compute_units: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x4e7e_7063);
        let compute = (0..base.num_nodes())
            .map(|_| compute_units * rng.uniform_in(0.5, 1.5))
            .collect();
        let mut link = std::collections::BTreeMap::new();
        for &(u, v) in base.edges() {
            link.insert((u, v), rng.uniform_in(0.5, 2.0));
        }
        HeterogeneousPolicy { compute, link, default_link: 1.0 }
    }

    /// Explicit construction (tests, bespoke scenarios). Link keys are
    /// canonicalized to `u < v`, matching `link_time`'s lookups.
    pub fn from_parts(compute: Vec<f64>, link: Vec<((usize, usize), f64)>) -> Self {
        HeterogeneousPolicy {
            compute,
            link: link
                .into_iter()
                .map(|((u, v), t)| (if u < v { (u, v) } else { (v, u) }, t))
                .collect(),
            default_link: 1.0,
        }
    }
}

impl DelayPolicy for HeterogeneousPolicy {
    fn compute_time(&mut self, w: usize, _k: usize) -> f64 {
        self.compute[w]
    }

    fn link_time(&mut self, _j: usize, u: usize, v: usize, _k: usize) -> f64 {
        let key = if u < v { (u, v) } else { (v, u) };
        *self.link.get(&key).unwrap_or(&self.default_link)
    }

    fn name(&self) -> &'static str {
        "hetero"
    }
}

/// Straggler injection: wraps a base policy, multiplying the compute time
/// of the listed workers by `factor`. Because matchings serialize behind
/// the compute barrier, one straggler slows every iteration — the
/// scenario where decentralized (vs synchronous all-reduce) topologies
/// are claimed to help.
pub struct StragglerPolicy<B: DelayPolicy> {
    base: B,
    slow_workers: Vec<usize>,
    factor: f64,
}

impl<B: DelayPolicy> StragglerPolicy<B> {
    pub fn new(base: B, slow_workers: Vec<usize>, factor: f64) -> Self {
        assert!(factor >= 1.0, "straggler factor must be ≥ 1, got {factor}");
        StragglerPolicy { base, slow_workers, factor }
    }
}

impl<B: DelayPolicy> DelayPolicy for StragglerPolicy<B> {
    fn compute_time(&mut self, w: usize, k: usize) -> f64 {
        let base = self.base.compute_time(w, k);
        if self.slow_workers.contains(&w) {
            base * self.factor
        } else {
            base
        }
    }

    fn link_time(&mut self, j: usize, u: usize, v: usize, k: usize) -> f64 {
        self.base.link_time(j, u, v, k)
    }

    fn link_fails(&mut self, u: usize, v: usize, k: usize) -> bool {
        self.base.link_fails(u, v, k)
    }

    fn analytic_comm_time(&mut self, matchings: &[Graph], activated: &[usize]) -> Option<f64> {
        // Stragglers only touch compute time; the base's communication
        // model (including MaxDegree's closed form) passes through.
        self.base.analytic_comm_time(matchings, activated)
    }

    fn name(&self) -> &'static str {
        "straggler"
    }
}

/// Link-failure injection: wraps a base policy; each link transmission
/// independently fails with probability `fail_prob`. Failed links charge
/// their full time (timeout) and drop out of that round's mix — the
/// gossip update stays mean-preserving because the edge update is
/// antisymmetric.
///
/// Failure injection needs a *link-granular* base: a base whose
/// `analytic_comm_time` is `Some` (MaxDegree) bypasses the per-link
/// schedule entirely, so no `link_fails` calls would ever happen. The
/// wrapper forwards the base's override (keeping its timing exact) and
/// [`parse_policy`] rejects the `flaky`-over-`maxdeg` combination so the
/// CLI cannot silently request failures that never trigger.
pub struct FlakyLinkPolicy<B: DelayPolicy> {
    base: B,
    fail_prob: f64,
    rng: Rng,
}

impl<B: DelayPolicy> FlakyLinkPolicy<B> {
    pub fn new(base: B, fail_prob: f64, seed: u64) -> Self {
        assert!(
            (0.0..=1.0).contains(&fail_prob),
            "fail probability {fail_prob} out of range"
        );
        FlakyLinkPolicy { base, fail_prob, rng: Rng::new(seed ^ 0xf1a2_b3c4) }
    }
}

impl<B: DelayPolicy> DelayPolicy for FlakyLinkPolicy<B> {
    fn compute_time(&mut self, w: usize, k: usize) -> f64 {
        self.base.compute_time(w, k)
    }

    fn link_time(&mut self, j: usize, u: usize, v: usize, k: usize) -> f64 {
        self.base.link_time(j, u, v, k)
    }

    fn link_fails(&mut self, _u: usize, _v: usize, _k: usize) -> bool {
        self.rng.bernoulli(self.fail_prob)
    }

    fn analytic_comm_time(&mut self, matchings: &[Graph], activated: &[usize]) -> Option<f64> {
        // Forward the base's closed form so a wrapped MaxDegree model
        // keeps its exact timing — at the documented cost that such a
        // base never reaches the per-link schedule, hence never fails.
        self.base.analytic_comm_time(matchings, activated)
    }

    fn name(&self) -> &'static str {
        "flaky"
    }
}

/// Parse a policy spec string into a boxed policy.
///
/// Forms: `analytic` | `hetero:SEED` | `straggler:WORKER:FACTOR` |
/// `flaky:PROB`. `straggler` and `flaky` wrap the analytic policy built
/// from `config` (so `--delay` still selects the underlying link model).
pub fn parse_policy(
    spec: &str,
    base: &Graph,
    config: &crate::sim::RunConfig,
) -> Result<Box<dyn DelayPolicy>, String> {
    const USAGE: &str = "expected analytic | hetero:SEED | straggler:WORKER:FACTOR | flaky:PROB";
    let parts: Vec<&str> = spec.split(':').collect();
    let analytic = || AnalyticPolicy::matching_run_config(config);
    match parts[0] {
        "analytic" => {
            if parts.len() != 1 {
                return Err(format!("policy '{spec}': analytic takes no arguments ({USAGE})"));
            }
            Ok(Box::new(analytic()))
        }
        "hetero" => {
            if parts.len() != 2 {
                return Err(format!("policy '{spec}': {USAGE}"));
            }
            let seed: u64 = parts[1]
                .parse()
                .map_err(|e| format!("policy '{spec}': bad seed: {e}"))?;
            Ok(Box::new(HeterogeneousPolicy::generate(base, config.compute_units, seed)))
        }
        "straggler" => {
            if parts.len() != 3 {
                return Err(format!("policy '{spec}': {USAGE}"));
            }
            let w: usize = parts[1]
                .parse()
                .map_err(|e| format!("policy '{spec}': bad worker: {e}"))?;
            if w >= base.num_nodes() {
                return Err(format!(
                    "policy '{spec}': worker {w} out of range for {} nodes",
                    base.num_nodes()
                ));
            }
            let f: f64 = parts[2]
                .parse()
                .map_err(|e| format!("policy '{spec}': bad factor: {e}"))?;
            if f < 1.0 {
                return Err(format!("policy '{spec}': factor must be ≥ 1"));
            }
            Ok(Box::new(StragglerPolicy::new(analytic(), vec![w], f)))
        }
        "flaky" => {
            if parts.len() != 2 {
                return Err(format!("policy '{spec}': {USAGE}"));
            }
            if matches!(config.delay, DelayModel::MaxDegree) {
                return Err(format!(
                    "policy '{spec}': link-failure injection needs a link-granular \
                     delay model; --delay maxdeg has no per-link schedule \
                     (use --delay unit or stochastic:lo:hi)"
                ));
            }
            let p: f64 = parts[1]
                .parse()
                .map_err(|e| format!("policy '{spec}': bad probability: {e}"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("policy '{spec}': probability {p} out of [0,1]"));
            }
            Ok(Box::new(FlakyLinkPolicy::new(analytic(), p, config.seed)))
        }
        other => Err(format!("unknown policy '{other}' ({USAGE})")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;
    use crate::matching::decompose;
    use crate::sim::RunConfig;

    #[test]
    fn analytic_unit_matches_closed_form() {
        let d = decompose(&paper_figure1_graph());
        let cfg = RunConfig::default();
        let mut p = AnalyticPolicy::matching_run_config(&cfg);
        // Per-matching time = max over links of link_time = 1; summed over
        // two activated matchings = closed form's activated count.
        let mut total = 0.0;
        for &j in &[0usize, 2] {
            let mut mt: f64 = 0.0;
            for &(u, v) in d.matchings[j].edges() {
                mt = mt.max(p.link_time(j, u, v, 0));
            }
            total += mt;
        }
        let mut rng = cfg.delay_rng();
        assert_eq!(total, cfg.delay.comm_time(&d.matchings, &[0, 2], &mut rng));
    }

    #[test]
    fn analytic_stochastic_matches_closed_form_stream() {
        let d = decompose(&paper_figure1_graph());
        let cfg = RunConfig {
            delay: DelayModel::StochasticLink { min_units: 0.5, max_units: 2.0 },
            seed: 77,
            ..RunConfig::default()
        };
        let mut p = AnalyticPolicy::matching_run_config(&cfg);
        let activated = vec![0usize, 1];
        let mut total = 0.0;
        for &j in &activated {
            let mut mt: f64 = 0.0;
            for &(u, v) in d.matchings[j].edges() {
                mt = mt.max(p.link_time(j, u, v, 0));
            }
            total += mt;
        }
        let mut rng = cfg.delay_rng();
        let closed = cfg.delay.comm_time(&d.matchings, &activated, &mut rng);
        assert_eq!(total, closed, "same stream, same order -> identical total");
    }

    #[test]
    fn analytic_maxdeg_uses_override() {
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let cfg = RunConfig { delay: DelayModel::MaxDegree, ..RunConfig::default() };
        let mut p = AnalyticPolicy::matching_run_config(&cfg);
        let all: Vec<usize> = (0..d.len()).collect();
        let t = p.analytic_comm_time(&d.matchings, &all).unwrap();
        assert_eq!(t, g.max_degree() as f64);
        // Other models do not override.
        let mut unit = AnalyticPolicy::matching_run_config(&RunConfig::default());
        assert!(unit.analytic_comm_time(&d.matchings, &all).is_none());
    }

    #[test]
    fn straggler_slows_only_listed_workers() {
        let cfg = RunConfig::default();
        let base = AnalyticPolicy::matching_run_config(&cfg);
        let mut p = StragglerPolicy::new(base, vec![2], 5.0);
        assert_eq!(p.compute_time(0, 0), 1.0);
        assert_eq!(p.compute_time(2, 0), 5.0);
        assert_eq!(p.link_time(0, 0, 1, 0), 1.0);
    }

    #[test]
    fn flaky_failure_frequency_tracks_probability() {
        let cfg = RunConfig::default();
        let base = AnalyticPolicy::matching_run_config(&cfg);
        let mut p = FlakyLinkPolicy::new(base, 0.3, 9);
        let n = 20_000;
        let fails = (0..n).filter(|&k| p.link_fails(0, 1, k)).count();
        let freq = fails as f64 / n as f64;
        assert!((freq - 0.3).abs() < 0.02, "freq {freq}");
    }

    #[test]
    fn hetero_is_deterministic_and_in_band() {
        let g = paper_figure1_graph();
        let mut a = HeterogeneousPolicy::generate(&g, 1.0, 4);
        let mut b = HeterogeneousPolicy::generate(&g, 1.0, 4);
        for w in 0..g.num_nodes() {
            let t = a.compute_time(w, 0);
            assert_eq!(t, b.compute_time(w, 0));
            assert!((0.5..1.5).contains(&t));
        }
        for &(u, v) in g.edges() {
            let t = a.link_time(0, u, v, 0);
            assert_eq!(t, b.link_time(0, v, u, 0), "orientation-independent");
            assert!((0.5..2.0).contains(&t));
        }
    }

    #[test]
    fn wrappers_forward_the_maxdeg_closed_form() {
        // Regression: a straggler wrapped over MaxDegree must keep the
        // closed-form communication time, not fall through to the
        // event path's unit-time placeholder.
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let cfg = RunConfig { delay: DelayModel::MaxDegree, ..RunConfig::default() };
        let all: Vec<usize> = (0..d.len()).collect();
        let mut wrapped =
            StragglerPolicy::new(AnalyticPolicy::matching_run_config(&cfg), vec![1], 3.0);
        assert_eq!(
            wrapped.analytic_comm_time(&d.matchings, &all),
            Some(g.max_degree() as f64)
        );
        let mut flaky =
            FlakyLinkPolicy::new(AnalyticPolicy::matching_run_config(&cfg), 0.1, 2);
        assert_eq!(
            flaky.analytic_comm_time(&d.matchings, &all),
            Some(g.max_degree() as f64)
        );
    }

    #[test]
    fn parse_policy_rejects_flaky_over_maxdeg() {
        let g = paper_figure1_graph();
        let cfg = RunConfig { delay: DelayModel::MaxDegree, ..RunConfig::default() };
        let r = parse_policy("flaky:0.2", &g, &cfg);
        assert!(r.is_err());
        assert!(r.unwrap_err().contains("link-granular"));
        // Straggler over maxdeg is fine (communication passes through).
        assert!(parse_policy("straggler:0:2.0", &g, &cfg).is_ok());
    }

    #[test]
    fn from_parts_canonicalizes_link_keys() {
        let mut p = HeterogeneousPolicy::from_parts(vec![1.0; 3], vec![((2, 1), 5.0)]);
        assert_eq!(p.link_time(0, 1, 2, 0), 5.0);
        assert_eq!(p.link_time(0, 2, 1, 0), 5.0);
    }

    #[test]
    fn parse_policy_accepts_valid_forms() {
        let g = paper_figure1_graph();
        let cfg = RunConfig::default();
        for spec in ["analytic", "hetero:3", "straggler:0:4.0", "flaky:0.2"] {
            assert!(parse_policy(spec, &g, &cfg).is_ok(), "{spec}");
        }
        assert_eq!(parse_policy("analytic", &g, &cfg).unwrap().name(), "analytic");
    }

    #[test]
    fn parse_policy_rejects_malformed_forms() {
        let g = paper_figure1_graph();
        let cfg = RunConfig::default();
        for spec in [
            "",
            "bogus",
            "hetero",
            "hetero:x",
            "straggler",
            "straggler:0",
            "straggler:99:2.0",
            "straggler:0:0.5",
            "flaky",
            "flaky:2.0",
            "flaky:x",
            "analytic:1",
        ] {
            let r = parse_policy(spec, &g, &cfg);
            assert!(r.is_err(), "spec '{spec}' should be rejected");
            let msg = r.unwrap_err();
            assert!(
                msg.contains("policy"),
                "error for '{spec}' should name the policy context: {msg}"
            );
        }
    }
}
