//! Discrete-event queue for the execution engine.
//!
//! Virtual time advances by popping events in `(time, sequence)` order.
//! The sequence number makes ties deterministic: two events scheduled at
//! the same instant pop in scheduling order, independent of heap
//! internals — a requirement for the engine's bit-for-bit deterministic
//! mode.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happened in the simulated cluster.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Worker `worker` finished its local gradient step for iteration `k`.
    ComputeDone { worker: usize, k: usize },
    /// Link `edge` of matching `matching` finished transmitting at
    /// iteration `k`. `failed` marks a link dropped by failure injection
    /// (the time still elapses — a detection timeout — but the edge is
    /// excluded from the mix).
    LinkDone { matching: usize, edge: (usize, usize), k: usize, failed: bool },
}

/// A scheduled event.
#[derive(Clone, Debug)]
pub struct Event {
    pub time: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-queue over [`Event`]s with deterministic tie-breaking and a
/// processed-event counter (exposed in engine results for observability).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
    processed: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at absolute virtual time `time`.
    pub fn schedule(&mut self, time: f64, kind: EventKind) {
        assert!(time.is_finite(), "non-finite event time {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<Event> {
        let e = self.heap.pop();
        if e.is_some() {
            self.processed += 1;
        }
        e
    }

    /// Drain every pending event (earliest first). Used when the caller
    /// wants to inspect the popped events (tests, tracing).
    pub fn drain(&mut self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.heap.len());
        while let Some(e) = self.pop() {
            out.push(e);
        }
        out
    }

    /// Pop and discard every pending event (earliest first), returning
    /// how many were processed. The allocation-free phase barrier for the
    /// engine's hot loop.
    pub fn run_to_barrier(&mut self) -> usize {
        let mut n = 0;
        while self.pop().is_some() {
            n += 1;
        }
        n
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Total events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, EventKind::ComputeDone { worker: 3, k: 0 });
        q.schedule(1.0, EventKind::ComputeDone { worker: 1, k: 0 });
        q.schedule(2.0, EventKind::ComputeDone { worker: 2, k: 0 });
        let order: Vec<f64> = q.drain().iter().map(|e| e.time).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_by_scheduling_order() {
        let mut q = EventQueue::new();
        for w in 0..5 {
            q.schedule(1.0, EventKind::ComputeDone { worker: w, k: 7 });
        }
        let workers: Vec<usize> = q
            .drain()
            .iter()
            .map(|e| match e.kind {
                EventKind::ComputeDone { worker, .. } => worker,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(workers, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn run_to_barrier_counts_and_empties() {
        let mut q = EventQueue::new();
        for w in 0..4 {
            q.schedule(w as f64, EventKind::ComputeDone { worker: w, k: 0 });
        }
        assert_eq!(q.run_to_barrier(), 4);
        assert!(q.is_empty());
        assert_eq!(q.processed(), 4);
        assert_eq!(q.run_to_barrier(), 0);
    }

    #[test]
    fn empty_queue_behaviour() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
        assert!(q.pop().is_none());
        assert_eq!(q.processed(), 0);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.schedule(f64::NAN, EventKind::ComputeDone { worker: 0, k: 0 });
    }
}
