//! Event-driven parallel execution engine for decentralized SGD.
//!
//! The paper's core claim is a *wallclock* win: decomposing the topology
//! into matchings lets node-disjoint links run concurrently. The
//! sequential simulator ([`crate::sim`]) charges that time with a
//! closed-form per-iteration formula; this subsystem instead **executes**
//! it, at per-link granularity, on real cores:
//!
//! - [`event`] — a discrete-event queue with deterministic tie-breaking;
//!   virtual time advances by link-transmission and worker-compute
//!   events.
//! - [`policy`] — the [`DelayPolicy`] trait generalizes
//!   [`crate::delay::DelayModel`] (now one analytic policy among several)
//!   to heterogeneous links ([`HeterogeneousPolicy`]), straggler
//!   injection ([`StragglerPolicy`]) and link failures
//!   ([`FlakyLinkPolicy`]).
//! - [`actor`] — logical workers multiplexed over a bounded pool of OS
//!   threads ([`crate::gossip::ShardedPool`], shared with the
//!   asynchronous gossip runtime); each shard owns its workers' iterates
//!   in a private [`crate::state::StateMatrix`] arena segment next to
//!   their RNG streams, and exchanges phase commands over `mpsc`
//!   channels. Gossip messages are metadata plus staged peer rows in
//!   recycled flat buffers — no per-message cloning.
//! - [`runner`] — the engine loop: compute phase → link events → gossip
//!   mix, with a barrier per iteration (**deterministic mode**). Under
//!   [`AnalyticPolicy`] the trajectory and the virtual clock reproduce
//!   [`crate::sim::run_decentralized`] **bit-for-bit** — the step/mix
//!   math lives once in [`crate::state::kernel`] (bound to run semantics
//!   by [`crate::sim::kernel`]) and is shared by both paths (enforced by
//!   the property tests in `rust/tests/engine.rs` and the golden
//!   fixtures in `rust/tests/golden.rs`).
//! - [`sweep`] — a parallel sweep driver that fans independent
//!   budget/topology grid points across cores (the figure harnesses'
//!   serial loops, parallelized).
//!
//! For a **barrier-free** execution mode on the same event queue and
//! delay policies — asynchronous gossip with staleness-aware mixing —
//! see [`crate::gossip`].
//!
//! (`no_run`: the example spawns the bounded actor pool; the same path
//! is executed for real by `rust/tests/engine.rs`.)
//!
//! ```no_run
//! use matcha::engine::{run_engine_analytic, EngineConfig};
//! use matcha::graph::paper_figure1_graph;
//! use matcha::matching::decompose;
//! use matcha::rng::Rng;
//! use matcha::sim::{QuadraticProblem, RunConfig};
//! use matcha::topology::VanillaSampler;
//!
//! let d = decompose(&paper_figure1_graph());
//! let problem = QuadraticProblem::generate(8, 10, 1.0, 0.1, &mut Rng::new(1));
//! let mut sampler = VanillaSampler::new(d.len());
//! let config = EngineConfig { run: RunConfig::default(), threads: 8 };
//! let result = run_engine_analytic(&problem, &d.matchings, &mut sampler, &config);
//! println!("virtual time: {}", result.run.total_time);
//! ```

pub mod actor;
pub mod event;
pub mod policy;
pub mod runner;
pub mod sweep;

pub use event::{Event, EventKind, EventQueue};
pub use policy::{
    parse_policy, AnalyticPolicy, DelayPolicy, FlakyLinkPolicy, HeterogeneousPolicy,
    StragglerPolicy,
};
pub use runner::{
    run_engine, run_engine_analytic, run_engine_observed, run_engine_traced, EngineConfig,
    EngineResult,
};
pub use sweep::{available_threads, sweep_parallel, sweep_parallel_streaming, sweep_serial};
