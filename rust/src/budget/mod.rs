//! Activation-probability optimization (Step 2 of MATCHA, problem (4)).
//!
//! Given the matching decomposition `G = ∪ G_j` and a communication
//! budget `CB`, choose activation probabilities `p ∈ [0,1]^M` maximizing
//! the algebraic connectivity of the *expected* activated topology:
//!
//! ```text
//!   max  λ₂( Σ_j p_j L_j )   s.t.   Σ_j p_j ≤ CB·M,  0 ≤ p_j ≤ 1.
//! ```
//!
//! λ₂ is concave in `p` (it is a minimum of linear functions of `p` over
//! the subspace ⊥ 1), so this is a convex program. The paper solves it
//! with an off-the-shelf SDP/convex solver; none exists in this offline
//! image, so we use **projected supergradient ascent**: the standard
//! supergradient of λ₂ at `p` is `g_j = v₂ᵀ L_j v₂` where `v₂` is a unit
//! Fiedler vector of `Σ p_j L_j`, and the feasible set — WLOG the *capped
//! simplex* `{p ∈ [0,1]^M : Σp = min(CB·M, M)}`, since λ₂ is monotone in
//! every `p_j` — admits an exact O(M log 1/ε) projection by bisection.
//! Correctness is cross-checked against brute-force grids in the tests.

mod simplex;

pub use simplex::project_capped_simplex;

use crate::graph::lambda2_of;
use crate::linalg::{fiedler_pair, Mat};
use crate::matching::MatchingDecomposition;

/// Result of the activation-probability optimization.
#[derive(Clone, Debug)]
pub struct ActivationProbabilities {
    /// One probability per matching, aligned with `decomposition.matchings`.
    pub probabilities: Vec<f64>,
    /// λ₂ of the expected Laplacian Σ p_j L_j at the optimum.
    pub lambda2: f64,
    /// The communication budget this was optimized for.
    pub budget: f64,
}

impl ActivationProbabilities {
    /// Expected communication time per iteration, Σ p_j (paper eq. (3)).
    pub fn expected_comm_time(&self) -> f64 {
        self.probabilities.iter().sum()
    }
}

/// Expected Laplacian `L̄(p) = Σ_j p_j L_j`.
pub fn expected_laplacian(laplacians: &[Mat], probs: &[f64]) -> Mat {
    assert_eq!(laplacians.len(), probs.len());
    assert!(!laplacians.is_empty());
    let n = laplacians[0].rows();
    let mut l = Mat::zeros(n, n);
    for (lj, &p) in laplacians.iter().zip(probs) {
        l.axpy(p, lj);
    }
    l
}

/// Solve problem (4) by projected supergradient ascent.
///
/// `cb` is the communication budget in `(0, 1]`: the fraction of vanilla
/// DecenSGD's per-iteration communication time (`CB·M` expected units).
/// Returns probabilities on the capped simplex `Σp = min(CB·M, M)`.
pub fn optimize_activation_probabilities(
    decomp: &MatchingDecomposition,
    cb: f64,
) -> ActivationProbabilities {
    assert!(cb > 0.0 && cb <= 1.0, "communication budget must be in (0,1], got {cb}");
    let laps = decomp.laplacians();
    let m_matchings = laps.len();
    let total = (cb * m_matchings as f64).min(m_matchings as f64);

    // Everything activates: nothing to optimize.
    if (total - m_matchings as f64).abs() < 1e-12 {
        let probs = vec![1.0; m_matchings];
        let l2 = lambda2_of(&expected_laplacian(&laps, &probs));
        return ActivationProbabilities { probabilities: probs, lambda2: l2, budget: cb };
    }

    // Uniform feasible start.
    let mut p = vec![total / m_matchings as f64; m_matchings];
    let mut best_p = p.clone();
    let mut best_l2 = f64::NEG_INFINITY;

    // Diminishing-step projected supergradient ascent. λ₂ values are
    // O(1)–O(m); normalize steps by the supergradient norm. One
    // eigendecomposition per iteration supplies BOTH the objective value
    // (λ₂ of the current iterate) and the supergradient direction (its
    // Fiedler vector); we stop early once the incumbent stops improving.
    let iters = 400;
    let patience = 80;
    let mut stale = 0;
    for t in 0..iters {
        let lbar = expected_laplacian(&laps, &p);
        let (l2, v2) = fiedler_pair(&lbar);
        if l2 > best_l2 + 1e-12 {
            best_l2 = l2;
            best_p = p.clone();
            stale = 0;
        } else {
            stale += 1;
            if stale >= patience {
                break;
            }
        }
        // Supergradient: g_j = v₂ᵀ L_j v₂ ≥ 0.
        let g: Vec<f64> = laps.iter().map(|lj| lj.quad_form(&v2)).collect();
        let gnorm = g.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        let step = 0.5 / ((t as f64 + 1.0).sqrt() * gnorm);
        for j in 0..m_matchings {
            p[j] += step * g[j];
        }
        p = project_capped_simplex(&p, total);
    }
    // Evaluate the final iterate too (the loop records before stepping).
    let final_l2 = lambda2_of(&expected_laplacian(&laps, &p));
    if final_l2 > best_l2 {
        best_l2 = final_l2;
        best_p = p;
    }

    ActivationProbabilities { probabilities: best_p, lambda2: best_l2.max(0.0), budget: cb }
}

/// The P-DecenSGD (periodic) allocation at the same budget: every
/// matching shares one probability `CB` (all links activate together).
/// Benchmark comparator from §3/§5 of the paper.
pub fn periodic_probabilities(decomp: &MatchingDecomposition, cb: f64) -> ActivationProbabilities {
    assert!(cb > 0.0 && cb <= 1.0);
    let laps = decomp.laplacians();
    let probs = vec![cb; laps.len()];
    let l2 = lambda2_of(&expected_laplacian(&laps, &probs));
    ActivationProbabilities { probabilities: probs, lambda2: l2, budget: cb }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{paper_figure1_graph, ring, star};
    use crate::matching::decompose;

    #[test]
    fn budget_one_activates_everything() {
        let d = decompose(&paper_figure1_graph());
        let a = optimize_activation_probabilities(&d, 1.0);
        for &p in &a.probabilities {
            assert!((p - 1.0).abs() < 1e-9);
        }
        let base_l2 = crate::graph::algebraic_connectivity(&d.base);
        assert!((a.lambda2 - base_l2).abs() < 1e-6);
    }

    #[test]
    fn respects_budget_constraint() {
        let d = decompose(&paper_figure1_graph());
        for cb in [0.1, 0.3, 0.5, 0.8] {
            let a = optimize_activation_probabilities(&d, cb);
            let total: f64 = a.probabilities.iter().sum();
            assert!(
                total <= cb * d.len() as f64 + 1e-6,
                "cb={cb}: Σp = {total} > {}",
                cb * d.len() as f64
            );
            for &p in &a.probabilities {
                assert!((-1e-9..=1.0 + 1e-9).contains(&p), "p={p} out of box");
            }
        }
    }

    #[test]
    fn expected_topology_connected_for_positive_budget() {
        // Theorem 2 part 1: λ₂(Σ p_j L_j) > 0 whenever CB > 0 and the
        // base graph is connected.
        for g in [paper_figure1_graph(), ring(9), star(6)] {
            let d = decompose(&g);
            for cb in [0.05, 0.2, 0.5] {
                let a = optimize_activation_probabilities(&d, cb);
                assert!(
                    a.lambda2 > 1e-6,
                    "cb={cb}: expected graph disconnected (λ₂={})",
                    a.lambda2
                );
            }
        }
    }

    #[test]
    fn lambda2_monotone_in_budget() {
        let d = decompose(&paper_figure1_graph());
        let mut prev = 0.0;
        for cb in [0.1, 0.25, 0.5, 0.75, 1.0] {
            let a = optimize_activation_probabilities(&d, cb);
            assert!(
                a.lambda2 >= prev - 1e-6,
                "λ₂ decreased from {prev} to {} at cb={cb}",
                a.lambda2
            );
            prev = a.lambda2;
        }
    }

    #[test]
    fn optimizer_beats_uniform_allocation() {
        // MATCHA's optimized probabilities must do at least as well as the
        // uniform (periodic-style) split at the same budget.
        let d = decompose(&paper_figure1_graph());
        for cb in [0.2, 0.4, 0.6] {
            let opt = optimize_activation_probabilities(&d, cb);
            let uni = periodic_probabilities(&d, cb);
            assert!(
                opt.lambda2 >= uni.lambda2 - 1e-7,
                "cb={cb}: optimized λ₂ {} < uniform λ₂ {}",
                opt.lambda2,
                uni.lambda2
            );
        }
    }

    #[test]
    fn critical_link_gets_high_priority() {
        // Paper Fig 1: the bridge (0,4) to the degree-1 node must be
        // activated with (near-)maximal probability at CB=0.5 while links
        // at the busiest node are throttled.
        let g = paper_figure1_graph();
        let d = decompose(&g);
        let a = optimize_activation_probabilities(&d, 0.5);
        // Find the matching containing edge (0,4).
        let crit = d
            .matchings
            .iter()
            .position(|m| m.has_edge(0, 4))
            .expect("some matching holds (0,4)");
        let p_crit = a.probabilities[crit];
        let mean_p: f64 = a.probabilities.iter().sum::<f64>() / a.probabilities.len() as f64;
        assert!(
            p_crit > mean_p,
            "critical matching p={p_crit} not above mean {mean_p}"
        );
    }

    #[test]
    fn near_optimal_vs_brute_force_small_case() {
        // Star on 4 nodes: 3 matchings of one edge each. By symmetry the
        // optimum at Σp = 1.5 is uniform p = 0.5; grid-search confirms.
        let d = decompose(&star(4));
        assert_eq!(d.len(), 3);
        let a = optimize_activation_probabilities(&d, 0.5);
        let laps = d.laplacians();
        // Brute force over the simplex Σp = 1.5, p ∈ [0,1]^3.
        let mut best = 0.0_f64;
        let steps = 60;
        for i in 0..=steps {
            for j in 0..=steps {
                let p1 = i as f64 / steps as f64;
                let p2 = j as f64 / steps as f64;
                let p3 = 1.5 - p1 - p2;
                if !(0.0..=1.0).contains(&p3) {
                    continue;
                }
                let l2 = lambda2_of(&expected_laplacian(&laps, &[p1, p2, p3]));
                best = best.max(l2);
            }
        }
        assert!(
            a.lambda2 >= best - 1e-3,
            "ascent λ₂ {} below brute force {best}",
            a.lambda2
        );
    }

    #[test]
    fn expected_comm_time_equals_probability_sum() {
        let d = decompose(&paper_figure1_graph());
        let a = optimize_activation_probabilities(&d, 0.3);
        let total: f64 = a.probabilities.iter().sum();
        assert!((a.expected_comm_time() - total).abs() < 1e-12);
    }
}
