//! Euclidean projection onto the capped simplex
//! `{ p ∈ [0,1]^M : Σ p = b }`.
//!
//! The projection of `y` has the form `p_j = clamp(y_j − τ, 0, 1)` for a
//! scalar Lagrange multiplier τ; `Σ_j clamp(y_j − τ, 0, 1)` is continuous
//! and non-increasing in τ, so τ is found by bisection to machine
//! precision in ~60 iterations.

/// Project `y` onto `{p ∈ [0,1]^n : Σp = b}`. Requires `0 ≤ b ≤ n`.
pub fn project_capped_simplex(y: &[f64], b: f64) -> Vec<f64> {
    let n = y.len();
    assert!(n > 0, "cannot project an empty vector");
    assert!(
        (0.0..=n as f64 + 1e-9).contains(&b),
        "target sum {b} infeasible for n={n}"
    );

    let sum_at = |tau: f64| -> f64 { y.iter().map(|&v| (v - tau).clamp(0.0, 1.0)).sum() };

    // Bracket τ: at τ = min(y) − 1 every coordinate saturates at 1 (sum = n);
    // at τ = max(y) every coordinate is 0.
    let mut lo = y.iter().cloned().fold(f64::INFINITY, f64::min) - 1.0;
    let mut hi = y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    // Guard: ensure bracket actually straddles b.
    debug_assert!(sum_at(lo) >= b - 1e-12 && sum_at(hi) <= b + 1e-12);

    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if sum_at(mid) > b {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let tau = 0.5 * (lo + hi);
    y.iter().map(|&v| (v - tau).clamp(0.0, 1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn assert_feasible(p: &[f64], b: f64) {
        for &v in p {
            assert!((-1e-9..=1.0 + 1e-9).contains(&v), "coordinate {v} out of box");
        }
        let s: f64 = p.iter().sum();
        assert!((s - b).abs() < 1e-7, "sum {s} != target {b}");
    }

    #[test]
    fn already_feasible_is_fixed_point() {
        let y = vec![0.2, 0.3, 0.5];
        let p = project_capped_simplex(&y, 1.0);
        for (a, b) in y.iter().zip(&p) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn uniform_when_all_equal() {
        let p = project_capped_simplex(&[5.0, 5.0, 5.0, 5.0], 2.0);
        for &v in &p {
            assert!((v - 0.5).abs() < 1e-7);
        }
    }

    #[test]
    fn caps_at_one() {
        // One huge coordinate must saturate at 1, remainder split.
        let p = project_capped_simplex(&[100.0, 0.0, 0.0], 1.5);
        assert!((p[0] - 1.0).abs() < 1e-7);
        assert!((p[1] - 0.25).abs() < 1e-6);
        assert!((p[2] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn boundary_budgets() {
        let p0 = project_capped_simplex(&[0.3, 0.8], 0.0);
        assert_feasible(&p0, 0.0);
        let pn = project_capped_simplex(&[0.3, 0.8], 2.0);
        assert_feasible(&pn, 2.0);
    }

    #[test]
    fn property_feasibility_random() {
        let mut rng = Rng::new(99);
        for _ in 0..500 {
            let n = 1 + rng.below(12);
            let y: Vec<f64> = (0..n).map(|_| rng.uniform_in(-3.0, 3.0)).collect();
            let b = rng.uniform() * n as f64;
            let p = project_capped_simplex(&y, b);
            assert_feasible(&p, b);
        }
    }

    #[test]
    fn property_is_closest_point_vs_random_candidates() {
        // Projection optimality: ‖y − p*‖ ≤ ‖y − q‖ for any feasible q.
        let mut rng = Rng::new(123);
        for _ in 0..100 {
            let n = 2 + rng.below(6);
            let y: Vec<f64> = (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
            let b = rng.uniform() * n as f64;
            let p = project_capped_simplex(&y, b);
            let dp: f64 = y.iter().zip(&p).map(|(a, c)| (a - c).powi(2)).sum();
            for _ in 0..20 {
                // Random feasible q: random point projected (feasible by
                // the feasibility property), perturbed within the set.
                let raw: Vec<f64> = (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
                let q = project_capped_simplex(&raw, b);
                let dq: f64 = y.iter().zip(&q).map(|(a, c)| (a - c).powi(2)).sum();
                assert!(dp <= dq + 1e-6, "projection not closest: {dp} > {dq}");
            }
        }
    }
}
