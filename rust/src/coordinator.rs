//! The decentralized NN-training coordinator (L3 over the XLA runtime).
//!
//! Executes the paper's training loop on the real model: each of the `m`
//! workers holds a flat parameter vector; per iteration every worker runs
//! the AOT-compiled `train_step` on a batch from its own corpus shard
//! (paper eq. (2)'s local gradient step), then the activated topology's
//! mixing matrix is applied through the AOT `mix` computation (the
//! consensus step). The schedule is pregenerated (apriori, §1), runtime
//! does zero scheduling work, and the virtual clock charges the paper's
//! delay model — see DESIGN.md §Hardware-Adaptation for why modelled time
//! is the right testbed here.

use crate::config::{ArtifactPaths, ModelMeta};
use crate::data::{BatchIter, Corpus};
use crate::delay::{DelayModel, VirtualClock};
use crate::graph::Graph;
use crate::matching::MatchingDecomposition;
use crate::metrics::Recorder;
use crate::rng::Rng;
use crate::runtime::{
    literal_f32, literal_i32, literal_scalar_f32, to_scalar_f32, to_vec_f32, Executable,
    Runtime,
};
use crate::topology::Schedule;
use anyhow::{Context, Result};

/// Configuration for one coordinated training run.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// Total iterations to run (bounded by the schedule length).
    pub steps: usize,
    pub lr: f32,
    /// Multiply lr by `lr_decay` every `lr_decay_every` steps.
    pub lr_decay: f32,
    pub lr_decay_every: usize,
    /// Evaluate held-out loss every this many steps.
    pub eval_every: usize,
    /// Use the Pallas-kernel train_step artifact (vs the XLA-fused one).
    pub use_pallas: bool,
    /// Computation time per iteration in delay units (relative to one
    /// link's communication time; the paper's CIFAR runs are
    /// communication-dominated, i.e. small values here).
    pub compute_units: f64,
    pub delay: DelayModel,
    /// Tokens per worker shard in the synthetic corpus.
    pub tokens_per_worker: usize,
    pub non_iid: bool,
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            steps: 200,
            lr: 0.5,
            lr_decay: 1.0,
            lr_decay_every: usize::MAX,
            eval_every: 50,
            use_pallas: false,
            compute_units: 1.0,
            delay: DelayModel::UnitPerMatching,
            tokens_per_worker: 20_000,
            non_iid: false,
            seed: 0,
        }
    }
}

/// Outcome of a coordinated run.
pub struct TrainReport {
    pub metrics: Recorder,
    pub final_train_loss: f64,
    pub final_eval_loss: f64,
    pub total_time_units: f64,
    pub total_comm_units: f64,
    pub wallclock_secs: f64,
}

/// The coordinator: owns the runtime, the compiled executables, the
/// worker states, and the data pipeline.
pub struct Trainer {
    meta: ModelMeta,
    train_exe: Executable,
    eval_exe: Executable,
    mix_exe: Executable,
    decomp: MatchingDecomposition,
    config: TrainerConfig,
}

impl Trainer {
    /// Load artifacts and compile the three computations.
    pub fn new(
        artifacts: &ArtifactPaths,
        decomp: MatchingDecomposition,
        config: TrainerConfig,
    ) -> Result<Trainer> {
        let meta = ModelMeta::load(&artifacts.meta()).map_err(anyhow::Error::msg)?;
        anyhow::ensure!(
            decomp.base.num_nodes() == meta.workers,
            "graph has {} nodes but artifacts were compiled for {} workers \
             (re-run `make artifacts WORKERS={}`)",
            decomp.base.num_nodes(),
            meta.workers,
            decomp.base.num_nodes()
        );
        let rt = Runtime::cpu()?;
        let train_exe = rt.load_hlo(&artifacts.train_step(config.use_pallas))?;
        let eval_exe = rt.load_hlo(&artifacts.eval_step())?;
        let mix_exe = rt.load_hlo(&artifacts.mix(config.use_pallas))?;
        Ok(Trainer { meta, train_exe, eval_exe, mix_exe, decomp, config })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Build the dense mixing matrix W = I − α Σ_{j∈activated} L_j as a
    /// row-major f32 buffer for the mix executable.
    fn mixing_w(&self, activated: &[usize], alpha: f64) -> Vec<f32> {
        let m = self.meta.workers;
        let mut w = vec![0.0f32; m * m];
        for i in 0..m {
            w[i * m + i] = 1.0;
        }
        for &j in activated {
            for &(u, v) in self.decomp.matchings[j].edges() {
                w[u * m + u] -= alpha as f32;
                w[v * m + v] -= alpha as f32;
                w[u * m + v] += alpha as f32;
                w[v * m + u] += alpha as f32;
            }
        }
        w
    }

    /// Run the schedule. `schedule.alpha` supplies α; iterations are
    /// `min(config.steps, schedule.rounds.len())`.
    pub fn run(&self, schedule: &Schedule) -> Result<TrainReport> {
        let cfg = &self.config;
        let meta = &self.meta;
        let m = meta.workers;
        let d = meta.param_count;
        let steps = cfg.steps.min(schedule.rounds.len());
        anyhow::ensure!(steps > 0, "empty schedule");

        // --- data ----------------------------------------------------
        let corpus = Corpus::synthesize(
            m,
            cfg.tokens_per_worker,
            (meta.batch * meta.seq_len * 4).max(4096),
            cfg.non_iid,
            cfg.seed,
        );
        let mut iters: Vec<BatchIter> = corpus
            .shards
            .iter()
            .enumerate()
            .map(|(w, s)| BatchIter::new(&s.tokens, meta.batch, meta.seq_len, cfg.seed ^ w as u64))
            .collect();
        let mut eval_iter = BatchIter::new(&corpus.eval, meta.batch, meta.seq_len, cfg.seed ^ 0xe7a1);
        // Fixed eval batches for a stable eval metric.
        let eval_batches: Vec<(Vec<i32>, Vec<i32>)> = (0..4).map(|_| eval_iter.next_batch()).collect();

        // --- worker states --------------------------------------------
        // All workers start from the same point (Theorem 1 initialization).
        let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
        let x0 = meta.init_params(&mut init_rng);
        let mut workers: Vec<Vec<f32>> = vec![x0; m];

        // --- loop ------------------------------------------------------
        let mut clock = VirtualClock::new(cfg.compute_units);
        let mut delay_rng = Rng::new(cfg.seed ^ 0xde1a);
        let mut metrics = Recorder::new();
        let mut total_comm = 0.0f64;
        let mut lr = cfg.lr;
        let batch_dims = [meta.batch as i64, meta.seq_len as i64];
        let wall_start = std::time::Instant::now();

        for k in 0..steps {
            // Local SGD step on every worker.
            let mut mean_loss = 0.0f64;
            for w in 0..m {
                let (xs, ys) = iters[w].next_batch();
                let inputs = [
                    literal_f32(&workers[w], &[d as i64])?,
                    literal_i32(&xs, &batch_dims)?,
                    literal_i32(&ys, &batch_dims)?,
                    literal_scalar_f32(lr),
                ];
                let outs = self
                    .train_exe
                    .run(&inputs)
                    .with_context(|| format!("train step k={k} worker={w}"))?;
                workers[w] = to_vec_f32(&outs[0])?;
                mean_loss += to_scalar_f32(&outs[1])? as f64 / m as f64;
            }

            // Consensus over the activated topology via the mix artifact.
            let round = &schedule.rounds[k];
            if !round.activated.is_empty() {
                let w_mat = self.mixing_w(&round.activated, schedule.alpha);
                let mut stacked = Vec::with_capacity(m * d);
                for wvec in &workers {
                    stacked.extend_from_slice(wvec);
                }
                let outs = self
                    .mix_exe
                    .run(&[
                        literal_f32(&w_mat, &[m as i64, m as i64])?,
                        literal_f32(&stacked, &[m as i64, d as i64])?,
                    ])
                    .with_context(|| format!("mix step k={k}"))?;
                let mixed = to_vec_f32(&outs[0])?;
                for (w, wvec) in workers.iter_mut().enumerate() {
                    wvec.copy_from_slice(&mixed[w * d..(w + 1) * d]);
                }
            }

            // Time accounting + metrics.
            let comm_t =
                cfg.delay
                    .comm_time(&self.decomp.matchings, &round.activated, &mut delay_rng);
            total_comm += comm_t;
            let now = clock.tick(comm_t);
            metrics.push("train_loss_vs_iter", k as f64, mean_loss);
            metrics.push("train_loss_vs_time", now, mean_loss);
            metrics.push("comm_units_vs_iter", k as f64, total_comm);

            if (k + 1) % cfg.lr_decay_every == 0 {
                lr *= cfg.lr_decay;
            }
            if (k + 1) % cfg.eval_every == 0 || k + 1 == steps {
                let eval = self.evaluate(&workers, &eval_batches, &batch_dims)?;
                metrics.push("eval_loss_vs_iter", (k + 1) as f64, eval);
                metrics.push("eval_loss_vs_time", now, eval);
            }
        }

        let final_eval = metrics.last("eval_loss_vs_iter").unwrap_or(f64::NAN);
        Ok(TrainReport {
            final_train_loss: metrics.last("train_loss_vs_iter").unwrap_or(f64::NAN),
            final_eval_loss: final_eval,
            total_time_units: clock.elapsed(),
            total_comm_units: total_comm,
            wallclock_secs: wall_start.elapsed().as_secs_f64(),
            metrics,
        })
    }

    /// Held-out loss of the averaged iterate x̄ (the paper's reported
    /// quantity is a function of the averaged model).
    fn evaluate(
        &self,
        workers: &[Vec<f32>],
        eval_batches: &[(Vec<i32>, Vec<i32>)],
        batch_dims: &[i64],
    ) -> Result<f64> {
        let d = self.meta.param_count;
        let m = workers.len();
        let mut mean = vec![0.0f32; d];
        for w in workers {
            for (a, &b) in mean.iter_mut().zip(w) {
                *a += b / m as f32;
            }
        }
        let mut acc = 0.0f64;
        for (xs, ys) in eval_batches {
            let outs = self.eval_exe.run(&[
                literal_f32(&mean, &[d as i64])?,
                literal_i32(xs, batch_dims)?,
                literal_i32(ys, batch_dims)?,
            ])?;
            acc += to_scalar_f32(&outs[0])? as f64 / eval_batches.len() as f64;
        }
        Ok(acc)
    }
}

/// Convenience: build the full MATCHA pipeline (decompose → probabilities
/// → α → schedule) for a base graph and budget, returning everything a
/// run needs. This is the library's "one call" entry point.
pub struct MatchaPlan {
    pub decomposition: MatchingDecomposition,
    pub probabilities: Vec<f64>,
    pub lambda2: f64,
    pub alpha: f64,
    pub rho: f64,
    pub schedule: Schedule,
}

/// Assemble a MATCHA plan: matching decomposition, optimized activation
/// probabilities at budget `cb`, optimized mixing weight, and a
/// pregenerated `steps`-round schedule.
pub fn plan_matcha(base: &Graph, cb: f64, steps: usize, seed: u64) -> MatchaPlan {
    use crate::budget::optimize_activation_probabilities;
    use crate::mixing::optimize_alpha;
    use crate::topology::MatchaSampler;

    let decomposition = crate::matching::decompose(base);
    let probs = optimize_activation_probabilities(&decomposition, cb);
    let mix = optimize_alpha(&decomposition, &probs.probabilities);
    let mut sampler = MatchaSampler::new(probs.probabilities.clone(), seed);
    let schedule = Schedule::generate(&mut sampler, mix.alpha, decomposition.len(), steps);
    MatchaPlan {
        decomposition,
        probabilities: probs.probabilities,
        lambda2: probs.lambda2,
        alpha: mix.alpha,
        rho: mix.rho,
        schedule,
    }
}

/// Assemble the vanilla-DecenSGD plan on the same graph (all matchings
/// every round, closed-form optimal α).
pub fn plan_vanilla(base: &Graph, steps: usize) -> MatchaPlan {
    use crate::mixing::vanilla_design;
    use crate::topology::VanillaSampler;

    let decomposition = crate::matching::decompose(base);
    let design = vanilla_design(&base.laplacian());
    let mut sampler = VanillaSampler::new(decomposition.len());
    let schedule = Schedule::generate(&mut sampler, design.alpha, decomposition.len(), steps);
    let m = decomposition.len();
    MatchaPlan {
        decomposition,
        probabilities: vec![1.0; m],
        lambda2: crate::graph::algebraic_connectivity(base),
        alpha: design.alpha,
        rho: design.rho,
        schedule,
    }
}

/// Assemble the P-DecenSGD plan at budget `cb` (full graph every ⌈1/cb⌉
/// rounds, α optimized for the correlated activation model).
pub fn plan_periodic(base: &Graph, cb: f64, steps: usize) -> MatchaPlan {
    use crate::mixing::optimize_alpha_periodic;
    use crate::topology::PeriodicSampler;

    let decomposition = crate::matching::decompose(base);
    let design = optimize_alpha_periodic(&base.laplacian(), cb);
    let mut sampler = PeriodicSampler::from_budget(decomposition.len(), cb);
    let schedule = Schedule::generate(&mut sampler, design.alpha, decomposition.len(), steps);
    let m = decomposition.len();
    MatchaPlan {
        decomposition,
        probabilities: vec![cb; m],
        lambda2: cb * crate::graph::algebraic_connectivity(base),
        alpha: design.alpha,
        rho: design.rho,
        schedule,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::paper_figure1_graph;

    #[test]
    fn plan_matcha_produces_consistent_artifacts() {
        let g = paper_figure1_graph();
        let plan = plan_matcha(&g, 0.5, 100, 1);
        assert_eq!(plan.schedule.rounds.len(), 100);
        assert!(plan.rho < 1.0);
        assert!(plan.alpha > 0.0);
        assert!(plan.lambda2 > 0.0);
        // Expected comm of the schedule tracks Σp.
        let target: f64 = plan.probabilities.iter().sum();
        let got = plan.schedule.mean_comm_units();
        assert!((got - target).abs() < 0.8, "schedule comm {got} vs Σp {target}");
    }

    #[test]
    fn plan_vanilla_activates_everything() {
        let g = paper_figure1_graph();
        let plan = plan_vanilla(&g, 10);
        for r in &plan.schedule.rounds {
            assert_eq!(r.activated.len(), plan.decomposition.len());
        }
    }

    #[test]
    fn plan_periodic_budget() {
        let g = paper_figure1_graph();
        let plan = plan_periodic(&g, 0.25, 100);
        let mean = plan.schedule.mean_comm_units();
        let full = plan.decomposition.len() as f64;
        assert!((mean - 0.25 * full).abs() < 0.05 * full, "mean {mean} vs {}", 0.25 * full);
    }

    #[test]
    fn mixing_w_construction_matches_linalg() {
        // Compare coordinator's W construction against topology::mixing_matrix.
        use crate::topology::mixing_matrix;
        let g = paper_figure1_graph();
        let plan = plan_matcha(&g, 0.4, 1, 2);
        // Fake a Trainer-like W build without artifacts: reuse the method's
        // logic via a standalone reimplementation here.
        let m = g.num_nodes();
        let alpha = plan.alpha;
        let activated: Vec<usize> = (0..plan.decomposition.len()).collect();
        let mut w = vec![0.0f32; m * m];
        for i in 0..m {
            w[i * m + i] = 1.0;
        }
        for &j in &activated {
            for &(u, v) in plan.decomposition.matchings[j].edges() {
                w[u * m + u] -= alpha as f32;
                w[v * m + v] -= alpha as f32;
                w[u * m + v] += alpha as f32;
                w[v * m + u] += alpha as f32;
            }
        }
        let wm = mixing_matrix(&plan.decomposition.laplacians(), &activated, alpha);
        for i in 0..m {
            for j in 0..m {
                assert!(
                    (wm.get(i, j) - w[i * m + j] as f64).abs() < 1e-6,
                    "W mismatch at ({i},{j})"
                );
            }
        }
    }
}
