//! Micro-benchmark harness (offline replacement for `criterion`).
//!
//! Bench targets in `rust/benches/` are built with `harness = false` and
//! use this module: warmup, multiple timed samples, median/mean/min
//! reporting, and a tabular printer for the paper-figure harnesses. The
//! statistics are deliberately simple — on this single-core testbed the
//! medians are stable to a few percent, which is all the perf pass needs.

use std::time::Instant;

/// Timing summary for one benchmark (all durations in nanoseconds).
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }

    pub fn report(&self) {
        println!(
            "bench {:<42} median {:>12}  mean {:>12}  min {:>12}  ({} samples)",
            self.name,
            Self::fmt_ns(self.median_ns),
            Self::fmt_ns(self.mean_ns),
            Self::fmt_ns(self.min_ns),
            self.samples
        );
    }
}

/// Run `f` repeatedly and collect stats. `f` should perform one logical
/// operation; use [`std::hint::black_box`] inside to defeat DCE.
pub fn bench<F: FnMut()>(name: &str, samples: usize, warmup: usize, mut f: F) -> BenchStats {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / samples as f64;
    let median = if samples % 2 == 1 {
        times[samples / 2]
    } else {
        0.5 * (times[samples / 2 - 1] + times[samples / 2])
    };
    let stats = BenchStats {
        name: name.to_string(),
        samples,
        mean_ns: mean,
        median_ns: median,
        min_ns: times[0],
        max_ns: *times.last().unwrap(),
    };
    stats.report();
    stats
}

/// Auto-calibrated bench: picks a sample count so the whole run takes
/// roughly `budget_ms` milliseconds (bounded to [5, 500] samples).
pub fn bench_auto<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchStats {
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as u64;
    let budget_ns = budget_ms * 1_000_000;
    let samples = ((budget_ns / one).clamp(5, 500)) as usize;
    bench(name, samples, samples.min(3), f)
}

/// Simple fixed-width table printer for paper-figure harness output.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:<w$} | ", w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|&w| "-".repeat(w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop-ish", 11, 2, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(s.samples, 11);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
        assert!(s.mean_ns > 0.0);
    }

    #[test]
    fn table_accepts_rows() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
