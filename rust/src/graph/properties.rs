//! Spectral and structural graph properties used by MATCHA's analysis:
//! algebraic connectivity (λ₂), spectral gaps, and expected-degree
//! statistics for activated topologies.

use super::Graph;
use crate::linalg::{fiedler_pair, symmetric_eigen, Mat};

/// Algebraic connectivity λ₂(L(G)) — the paper's objective in problem (4).
pub fn algebraic_connectivity(g: &Graph) -> f64 {
    if g.num_nodes() < 2 {
        return 0.0;
    }
    let (l2, _) = fiedler_pair(&g.laplacian());
    // Clamp tiny negative round-off; L is PSD.
    l2.max(0.0)
}

/// λ₂ of an arbitrary symmetric PSD matrix (e.g. the expected Laplacian
/// Σ pⱼ Lⱼ); clamps round-off below zero.
pub fn lambda2_of(l: &Mat) -> f64 {
    let (l2, _) = fiedler_pair(l);
    l2.max(0.0)
}

/// Full Laplacian spectrum, ascending.
pub fn laplacian_spectrum(g: &Graph) -> Vec<f64> {
    symmetric_eigen(&g.laplacian()).values
}

/// Per-node expected communication time for a set of matchings with
/// activation probabilities, under the unit-time-per-matching model:
/// node i pays 1 unit for matching j iff j is activated AND i is matched
/// in j. Used to regenerate the Figure-1 comparison.
pub fn expected_node_comm_time(
    m: usize,
    matchings: &[Graph],
    probs: &[f64],
) -> Vec<f64> {
    assert_eq!(matchings.len(), probs.len());
    let mut t = vec![0.0; m];
    for (g, &p) in matchings.iter().zip(probs) {
        let deg = g.degrees();
        for i in 0..m {
            if deg[i] > 0 {
                t[i] += p;
            }
        }
    }
    t
}

/// Expected degree of each node in the activated topology
/// E[Σⱼ Bⱼ deg_j(i)] = Σⱼ pⱼ deg_j(i). The paper (§5) observes MATCHA
/// keeps the *effective* maximal degree ≈ constant across base densities.
pub fn expected_node_degree(m: usize, matchings: &[Graph], probs: &[f64]) -> Vec<f64> {
    assert_eq!(matchings.len(), probs.len());
    let mut d = vec![0.0; m];
    for (g, &p) in matchings.iter().zip(probs) {
        for (i, &deg) in g.degrees().iter().enumerate() {
            d[i] += p * deg as f64;
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{complete, paper_figure1_graph, ring, star};

    #[test]
    fn lambda2_complete_graph() {
        // λ₂(K_n) = n.
        assert!((algebraic_connectivity(&complete(6)) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn lambda2_ring() {
        // λ₂(C_n) = 2 - 2cos(2π/n).
        let n = 8;
        let expected = 2.0 - 2.0 * (2.0 * std::f64::consts::PI / n as f64).cos();
        assert!((algebraic_connectivity(&ring(n)) - expected).abs() < 1e-9);
    }

    #[test]
    fn lambda2_star() {
        // λ₂(star on n nodes) = 1.
        assert!((algebraic_connectivity(&star(7)) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lambda2_positive_iff_connected() {
        let disconnected = Graph::new(4, &[(0, 1), (2, 3)]);
        assert!(algebraic_connectivity(&disconnected) < 1e-9);
        assert!(algebraic_connectivity(&paper_figure1_graph()) > 1e-6);
    }

    #[test]
    fn spectrum_starts_at_zero() {
        let s = laplacian_spectrum(&paper_figure1_graph());
        assert!(s[0].abs() < 1e-9);
        // Sum of eigenvalues = trace = 2|E|.
        let sum: f64 = s.iter().sum();
        assert!((sum - 24.0).abs() < 1e-8);
    }

    #[test]
    fn expected_comm_time_all_ones_counts_incident_matchings() {
        // Two matchings over 4 nodes; node 0 appears in both.
        let m1 = Graph::new(4, &[(0, 1)]);
        let m2 = Graph::new(4, &[(0, 2)]);
        let t = expected_node_comm_time(4, &[m1, m2], &[1.0, 1.0]);
        assert_eq!(t, vec![2.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn expected_degree_scales_with_probability() {
        let m1 = Graph::new(3, &[(0, 1)]);
        let d = expected_node_degree(3, &[m1], &[0.25]);
        assert!((d[0] - 0.25).abs() < 1e-12);
        assert!((d[2]).abs() < 1e-12);
    }
}
