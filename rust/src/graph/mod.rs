//! Communication-graph substrate.
//!
//! MATCHA operates on an arbitrary connected undirected graph of worker
//! nodes. This module provides the graph type, Laplacian/adjacency
//! construction, connectivity and degree analysis, and the generators
//! used across the paper's evaluation (the 8-node Figure-1 graph, random
//! geometric graphs, Erdős–Rényi graphs, plus standard references: ring,
//! star, complete, grid).

mod generators;
mod properties;

pub use generators::*;
pub use properties::*;

use crate::linalg::Mat;

/// An undirected simple graph over nodes `0..m`.
///
/// Edges are stored as a sorted, deduplicated list of `(u, v)` with
/// `u < v`. This is the "base communication topology" G of the paper.
#[derive(Clone, Debug, PartialEq)]
pub struct Graph {
    m: usize,
    edges: Vec<(usize, usize)>,
}

impl Graph {
    /// Build a graph from an edge list. Edges are normalized to `u < v`,
    /// deduplicated, and validated (no self-loops, endpoints < m).
    pub fn new(m: usize, edges: &[(usize, usize)]) -> Self {
        let mut es: Vec<(usize, usize)> = edges
            .iter()
            .map(|&(u, v)| {
                assert!(u != v, "self-loop ({u},{v}) not allowed in a simple graph");
                assert!(u < m && v < m, "edge ({u},{v}) out of range for m={m}");
                if u < v { (u, v) } else { (v, u) }
            })
            .collect();
        es.sort_unstable();
        es.dedup();
        Graph { m, edges: es }
    }

    /// Empty graph (no edges) on `m` nodes.
    pub fn empty(m: usize) -> Self {
        Graph { m, edges: vec![] }
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.m
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// The normalized edge list (`u < v`, sorted).
    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    /// Does the graph contain edge (u,v)?
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        let e = if u < v { (u, v) } else { (v, u) };
        self.edges.binary_search(&e).is_ok()
    }

    /// Degree of each node.
    pub fn degrees(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.m];
        for &(u, v) in &self.edges {
            d[u] += 1;
            d[v] += 1;
        }
        d
    }

    /// Maximal degree Δ(G) — the paper's communication bottleneck measure.
    pub fn max_degree(&self) -> usize {
        self.degrees().into_iter().max().unwrap_or(0)
    }

    /// Neighbor lists.
    pub fn adjacency_lists(&self) -> Vec<Vec<usize>> {
        let mut adj = vec![Vec::new(); self.m];
        for &(u, v) in &self.edges {
            adj[u].push(v);
            adj[v].push(u);
        }
        adj
    }

    /// Dense adjacency matrix A.
    pub fn adjacency_matrix(&self) -> Mat {
        let mut a = Mat::zeros(self.m, self.m);
        for &(u, v) in &self.edges {
            a.set(u, v, 1.0);
            a.set(v, u, 1.0);
        }
        a
    }

    /// Graph Laplacian `L = D - A`.
    pub fn laplacian(&self) -> Mat {
        let mut l = Mat::zeros(self.m, self.m);
        for &(u, v) in &self.edges {
            l.add_assign_at(u, u, 1.0);
            l.add_assign_at(v, v, 1.0);
            l.add_assign_at(u, v, -1.0);
            l.add_assign_at(v, u, -1.0);
        }
        l
    }

    /// Subgraph on the same vertex set induced by an edge subset.
    /// Panics if any edge is not in `self`.
    pub fn edge_subgraph(&self, edges: &[(usize, usize)]) -> Graph {
        for &(u, v) in edges {
            assert!(self.has_edge(u, v), "edge ({u},{v}) not in base graph");
        }
        Graph::new(self.m, edges)
    }

    /// Union of this graph's edges with another's (same node count).
    pub fn union(&self, other: &Graph) -> Graph {
        assert_eq!(self.m, other.m);
        let mut es = self.edges.clone();
        es.extend_from_slice(&other.edges);
        Graph::new(self.m, &es)
    }

    /// Connected-components labelling (BFS).
    pub fn components(&self) -> Vec<usize> {
        let adj = self.adjacency_lists();
        let mut comp = vec![usize::MAX; self.m];
        let mut next = 0;
        let mut queue = std::collections::VecDeque::new();
        for start in 0..self.m {
            if comp[start] != usize::MAX {
                continue;
            }
            comp[start] = next;
            queue.push_back(start);
            while let Some(u) = queue.pop_front() {
                for &v in &adj[u] {
                    if comp[v] == usize::MAX {
                        comp[v] = next;
                        queue.push_back(v);
                    }
                }
            }
            next += 1;
        }
        comp
    }

    /// Is the graph connected? (Paper requires a connected base graph.)
    pub fn is_connected(&self) -> bool {
        if self.m == 0 {
            return true;
        }
        self.components().iter().all(|&c| c == 0)
    }

    /// Is this graph a matching (max degree ≤ 1)? Definition 1 of the paper.
    pub fn is_matching(&self) -> bool {
        self.degrees().into_iter().all(|d| d <= 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizes_and_dedups_edges() {
        let g = Graph::new(4, &[(1, 0), (0, 1), (2, 3)]);
        assert_eq!(g.edges(), &[(0, 1), (2, 3)]);
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 2));
    }

    #[test]
    #[should_panic]
    fn rejects_self_loops() {
        Graph::new(3, &[(1, 1)]);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range() {
        Graph::new(3, &[(0, 3)]);
    }

    #[test]
    fn degrees_and_max_degree() {
        let g = Graph::new(4, &[(0, 1), (0, 2), (0, 3), (1, 2)]);
        assert_eq!(g.degrees(), vec![3, 2, 2, 1]);
        assert_eq!(g.max_degree(), 3);
    }

    #[test]
    fn laplacian_row_sums_zero() {
        let g = Graph::new(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (0, 2)]);
        let l = g.laplacian();
        for i in 0..5 {
            let s: f64 = l.row(i).iter().sum();
            assert!(s.abs() < 1e-12);
        }
        assert!(l.is_symmetric(1e-12));
        // trace = 2|E|
        assert!((l.trace() - 2.0 * g.num_edges() as f64).abs() < 1e-12);
    }

    #[test]
    fn connectivity() {
        let g = Graph::new(4, &[(0, 1), (2, 3)]);
        assert!(!g.is_connected());
        let g2 = g.union(&Graph::new(4, &[(1, 2)]));
        assert!(g2.is_connected());
    }

    #[test]
    fn matching_detection() {
        assert!(Graph::new(4, &[(0, 1), (2, 3)]).is_matching());
        assert!(!Graph::new(4, &[(0, 1), (1, 2)]).is_matching());
        assert!(Graph::empty(4).is_matching());
    }

    #[test]
    fn components_labelling() {
        let g = Graph::new(6, &[(0, 1), (1, 2), (4, 5)]);
        let c = g.components();
        assert_eq!(c[0], c[1]);
        assert_eq!(c[1], c[2]);
        assert_eq!(c[4], c[5]);
        assert_ne!(c[0], c[3]);
        assert_ne!(c[0], c[4]);
        assert_ne!(c[3], c[4]);
    }
}
