//! Graph generators used in the paper's evaluation plus standard
//! reference topologies.

use super::Graph;
use crate::rng::Rng;

/// The 8-node base communication graph of paper Figure 1.
///
/// The paper's figure is an image; we reconstruct a graph with the exact
/// stated properties: 8 nodes, maximal degree 5 (node 1 — the "busiest
/// node"), node 4 has degree 1 and its only link (0,4) is a cut edge
/// ("critical link"), and the graph is connected. Any schedule statistics
/// reported against "Fig 1" in this repo use this reconstruction
/// (documented in DESIGN.md).
pub fn paper_figure1_graph() -> Graph {
    Graph::new(
        8,
        &[
            (0, 1),
            (0, 4), // the critical (bridge) link to the degree-1 node
            (1, 2),
            (1, 3),
            (1, 5),
            (1, 7), // node 1 reaches degree 5
            (2, 3),
            (2, 6),
            (3, 6),
            (5, 6),
            (5, 7),
            (6, 7),
        ],
    )
}

/// Ring (cycle) graph C_m.
pub fn ring(m: usize) -> Graph {
    assert!(m >= 3, "ring needs at least 3 nodes");
    let edges: Vec<(usize, usize)> = (0..m).map(|i| (i, (i + 1) % m)).collect();
    Graph::new(m, &edges)
}

/// Star graph: node 0 connected to all others.
pub fn star(m: usize) -> Graph {
    assert!(m >= 2);
    let edges: Vec<(usize, usize)> = (1..m).map(|i| (0, i)).collect();
    Graph::new(m, &edges)
}

/// Complete graph K_m.
pub fn complete(m: usize) -> Graph {
    let mut edges = Vec::new();
    for u in 0..m {
        for v in (u + 1)..m {
            edges.push((u, v));
        }
    }
    Graph::new(m, &edges)
}

/// 2-D grid graph of `rows × cols` nodes.
pub fn grid(rows: usize, cols: usize) -> Graph {
    let m = rows * cols;
    let idx = |r: usize, c: usize| r * cols + c;
    let mut edges = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((idx(r, c), idx(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((idx(r, c), idx(r + 1, c)));
            }
        }
    }
    Graph::new(m, &edges)
}

/// Hypercube graph Q_d on 2^d nodes (a classic expander-ish topology the
/// decentralized-optimization literature uses; cf. the paper's refs on
/// expander graphs [6, 23]).
pub fn hypercube(dim: u32) -> Graph {
    let m = 1usize << dim;
    let mut edges = Vec::new();
    for u in 0..m {
        for b in 0..dim {
            let v = u ^ (1usize << b);
            if u < v {
                edges.push((u, v));
            }
        }
    }
    Graph::new(m, &edges)
}

/// 2-D torus (grid with wraparound), degree-4 regular.
pub fn torus(rows: usize, cols: usize) -> Graph {
    assert!(rows >= 3 && cols >= 3, "torus needs at least 3x3");
    let idx = |r: usize, c: usize| r * cols + c;
    let mut edges = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            edges.push((idx(r, c), idx(r, (c + 1) % cols)));
            edges.push((idx(r, c), idx((r + 1) % rows, c)));
        }
    }
    Graph::new(rows * cols, &edges)
}

/// Watts–Strogatz small-world graph: ring lattice with `k` neighbors per
/// side, each edge rewired with probability `beta`.
pub fn watts_strogatz(m: usize, k: usize, beta: f64, rng: &mut Rng) -> Graph {
    assert!(k >= 1 && 2 * k < m, "need 1 <= k < m/2");
    let mut edges = std::collections::BTreeSet::new();
    for u in 0..m {
        for j in 1..=k {
            let v = (u + j) % m;
            edges.insert(if u < v { (u, v) } else { (v, u) });
        }
    }
    let lattice: Vec<(usize, usize)> = edges.iter().copied().collect();
    for (u, v) in lattice {
        if rng.bernoulli(beta) {
            // Rewire (u,v) -> (u,w) for a uniform non-adjacent w.
            for _ in 0..32 {
                let w = rng.below(m);
                let e = if u < w { (u, w) } else { (w, u) };
                if w != u && w != v && !edges.contains(&e) {
                    edges.remove(&if u < v { (u, v) } else { (v, u) });
                    edges.insert(e);
                    break;
                }
            }
        }
    }
    let es: Vec<(usize, usize)> = edges.into_iter().collect();
    Graph::new(m, &es)
}

/// Random geometric graph: `m` nodes uniform in the unit square, edge iff
/// distance ≤ `radius`. The paper's 16-node topologies (Fig 5/9) are
/// random geometric graphs of varying density. Not guaranteed connected;
/// see [`geometric_connected`].
pub fn geometric(m: usize, radius: f64, rng: &mut Rng) -> Graph {
    let pts: Vec<(f64, f64)> = (0..m).map(|_| (rng.uniform(), rng.uniform())).collect();
    let mut edges = Vec::new();
    for u in 0..m {
        for v in (u + 1)..m {
            let dx = pts[u].0 - pts[v].0;
            let dy = pts[u].1 - pts[v].1;
            if (dx * dx + dy * dy).sqrt() <= radius {
                edges.push((u, v));
            }
        }
    }
    Graph::new(m, &edges)
}

/// Random geometric graph, resampled until connected (bounded retries).
pub fn geometric_connected(m: usize, radius: f64, rng: &mut Rng) -> Graph {
    for _ in 0..1000 {
        let g = geometric(m, radius, rng);
        if g.is_connected() {
            return g;
        }
    }
    panic!("geometric_connected: radius {radius} too small for m={m} (1000 attempts)");
}

/// Erdős–Rényi G(m, p). Paper Fig 3c uses a 16-node ER graph (Δ = 8).
pub fn erdos_renyi(m: usize, p: f64, rng: &mut Rng) -> Graph {
    let mut edges = Vec::new();
    for u in 0..m {
        for v in (u + 1)..m {
            if rng.bernoulli(p) {
                edges.push((u, v));
            }
        }
    }
    Graph::new(m, &edges)
}

/// Erdős–Rényi, resampled until connected (bounded retries).
pub fn erdos_renyi_connected(m: usize, p: f64, rng: &mut Rng) -> Graph {
    for _ in 0..1000 {
        let g = erdos_renyi(m, p, rng);
        if g.is_connected() {
            return g;
        }
    }
    panic!("erdos_renyi_connected: p {p} too small for m={m} (1000 attempts)");
}

/// The three 16-node geometric topologies of paper Figure 9, reconstructed
/// with seeded generators to hit the stated maximal degrees (≈6, 10, and
/// an ER graph with Δ=8). Returns (name, graph) pairs.
pub fn paper_figure9_topologies() -> Vec<(&'static str, Graph)> {
    // Seeds and radii chosen (deterministically, recorded here) so the
    // generated graphs are connected with the paper's stated max degrees.
    let sparse = find_geometric_with_max_degree(16, 6, 101);
    let dense = find_geometric_with_max_degree(16, 10, 202);
    let er = find_er_with_max_degree(16, 8, 303);
    vec![("geom-maxdeg6", sparse), ("geom-maxdeg10", dense), ("er-maxdeg8", er)]
}

/// Search seeded geometric graphs until one is connected with the target
/// maximal degree. Deterministic given `base_seed`.
pub fn find_geometric_with_max_degree(m: usize, target_delta: usize, base_seed: u64) -> Graph {
    for attempt in 0..20_000u64 {
        let mut rng = Rng::new(base_seed.wrapping_add(attempt));
        // Radius sweep correlated with the density we want.
        let radius = 0.25 + 0.35 * (target_delta as f64 / m as f64);
        let g = geometric(m, radius, &mut rng);
        if g.is_connected() && g.max_degree() == target_delta {
            return g;
        }
    }
    panic!("no geometric graph with m={m}, Δ={target_delta} found");
}

/// Search seeded ER graphs until one is connected with the target maximal
/// degree. Deterministic given `base_seed`.
pub fn find_er_with_max_degree(m: usize, target_delta: usize, base_seed: u64) -> Graph {
    for attempt in 0..20_000u64 {
        let mut rng = Rng::new(base_seed.wrapping_add(attempt));
        let p = target_delta as f64 / m as f64 * 0.8;
        let g = erdos_renyi(m, p, &mut rng);
        if g.is_connected() && g.max_degree() == target_delta {
            return g;
        }
    }
    panic!("no ER graph with m={m}, Δ={target_delta} found");
}

/// Parse a graph specification string used by the CLI:
/// `fig1`, `ring:m`, `star:m`, `complete:m`, `grid:RxC`,
/// `geom:m:delta:seed`, `er:m:delta:seed`.
pub fn parse_graph_spec(spec: &str) -> Result<Graph, String> {
    let parts: Vec<&str> = spec.split(':').collect();
    let usize_at = |i: usize| -> Result<usize, String> {
        parts
            .get(i)
            .ok_or_else(|| format!("graph spec '{spec}': missing field {i}"))?
            .parse::<usize>()
            .map_err(|e| format!("graph spec '{spec}': {e}"))
    };
    match parts[0] {
        "fig1" => Ok(paper_figure1_graph()),
        "ring" => Ok(ring(usize_at(1)?)),
        "star" => Ok(star(usize_at(1)?)),
        "complete" => Ok(complete(usize_at(1)?)),
        "hypercube" => Ok(hypercube(usize_at(1)? as u32)),
        "torus" => {
            let dims: Vec<&str> = parts
                .get(1)
                .ok_or_else(|| format!("graph spec '{spec}': missing RxC"))?
                .split('x')
                .collect();
            if dims.len() != 2 {
                return Err(format!("graph spec '{spec}': torus needs RxC"));
            }
            let r = dims[0].parse::<usize>().map_err(|e| e.to_string())?;
            let c = dims[1].parse::<usize>().map_err(|e| e.to_string())?;
            Ok(torus(r, c))
        }
        "smallworld" => {
            let (m, k, seed) = (usize_at(1)?, usize_at(2)?, usize_at(3)? as u64);
            Ok(watts_strogatz(m, k, 0.3, &mut Rng::new(seed)))
        }
        "grid" => {
            let dims: Vec<&str> = parts
                .get(1)
                .ok_or_else(|| format!("graph spec '{spec}': missing RxC"))?
                .split('x')
                .collect();
            if dims.len() != 2 {
                return Err(format!("graph spec '{spec}': grid needs RxC"));
            }
            let r = dims[0].parse::<usize>().map_err(|e| e.to_string())?;
            let c = dims[1].parse::<usize>().map_err(|e| e.to_string())?;
            Ok(grid(r, c))
        }
        "geom" => {
            let (m, delta, seed) = (usize_at(1)?, usize_at(2)?, usize_at(3)? as u64);
            Ok(find_geometric_with_max_degree(m, delta, seed))
        }
        "er" => {
            let (m, delta, seed) = (usize_at(1)?, usize_at(2)?, usize_at(3)? as u64);
            Ok(find_er_with_max_degree(m, delta, seed))
        }
        other => Err(format!("unknown graph spec kind '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_properties_match_paper() {
        let g = paper_figure1_graph();
        assert_eq!(g.num_nodes(), 8);
        assert!(g.is_connected());
        assert_eq!(g.max_degree(), 5, "paper: maximal degree is 5");
        let d = g.degrees();
        assert_eq!(d[1], 5, "node 1 is the degree-5 busiest node");
        assert_eq!(d[4], 1, "node 4 has degree 1");
        assert!(g.has_edge(0, 4), "critical link (0,4) present");
        // (0,4) is a cut edge: removing it disconnects node 4.
        let without: Vec<(usize, usize)> = g
            .edges()
            .iter()
            .copied()
            .filter(|&e| e != (0, 4))
            .collect();
        assert!(!Graph::new(8, &without).is_connected());
    }

    #[test]
    fn ring_star_complete_shapes() {
        assert_eq!(ring(6).degrees(), vec![2; 6]);
        assert_eq!(star(5).max_degree(), 4);
        let k5 = complete(5);
        assert_eq!(k5.num_edges(), 10);
        assert_eq!(k5.degrees(), vec![4; 5]);
        assert!(ring(6).is_connected() && star(5).is_connected() && k5.is_connected());
    }

    #[test]
    fn grid_shape() {
        let g = grid(3, 4);
        assert_eq!(g.num_nodes(), 12);
        // 3*(4-1) horizontal + (3-1)*4 vertical = 9 + 8
        assert_eq!(g.num_edges(), 17);
        assert!(g.is_connected());
        assert_eq!(g.max_degree(), 4);
    }

    #[test]
    fn geometric_is_deterministic_per_seed() {
        let g1 = geometric(16, 0.4, &mut Rng::new(9));
        let g2 = geometric(16, 0.4, &mut Rng::new(9));
        assert_eq!(g1, g2);
    }

    #[test]
    fn geometric_radius_monotone_in_edges() {
        let g_small = geometric(20, 0.2, &mut Rng::new(4));
        let g_big = geometric(20, 0.6, &mut Rng::new(4));
        assert!(g_big.num_edges() >= g_small.num_edges());
    }

    #[test]
    fn er_connected_helper() {
        let g = erdos_renyi_connected(12, 0.4, &mut Rng::new(21));
        assert!(g.is_connected());
    }

    #[test]
    fn figure9_topologies_hit_target_degrees() {
        let tops = paper_figure9_topologies();
        assert_eq!(tops.len(), 3);
        assert_eq!(tops[0].1.max_degree(), 6);
        assert_eq!(tops[1].1.max_degree(), 10);
        assert_eq!(tops[2].1.max_degree(), 8);
        for (name, g) in &tops {
            assert!(g.is_connected(), "{name} must be connected");
            assert_eq!(g.num_nodes(), 16);
        }
    }

    #[test]
    fn hypercube_properties() {
        let g = hypercube(4);
        assert_eq!(g.num_nodes(), 16);
        assert_eq!(g.degrees(), vec![4; 16]);
        assert_eq!(g.num_edges(), 32);
        assert!(g.is_connected());
    }

    #[test]
    fn torus_is_4_regular() {
        let g = torus(4, 5);
        assert_eq!(g.num_nodes(), 20);
        assert_eq!(g.degrees(), vec![4; 20]);
        assert!(g.is_connected());
    }

    #[test]
    fn watts_strogatz_preserves_edge_count_and_connects() {
        let mut rng = Rng::new(6);
        for beta in [0.0, 0.3, 1.0] {
            let g = watts_strogatz(20, 2, beta, &mut rng);
            // Rewiring preserves |E| = m·k.
            assert_eq!(g.num_edges(), 40, "beta={beta}");
        }
        // beta = 0 is the pure lattice: 4-regular and connected.
        let lattice = watts_strogatz(20, 2, 0.0, &mut Rng::new(1));
        assert_eq!(lattice.degrees(), vec![4; 20]);
        assert!(lattice.is_connected());
    }

    #[test]
    fn parse_specs() {
        assert_eq!(parse_graph_spec("fig1").unwrap(), paper_figure1_graph());
        assert_eq!(parse_graph_spec("ring:5").unwrap(), ring(5));
        assert_eq!(parse_graph_spec("grid:2x3").unwrap(), grid(2, 3));
        assert!(parse_graph_spec("nope").is_err());
        assert!(parse_graph_spec("ring:x").is_err());
    }
}
