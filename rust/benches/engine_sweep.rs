//! Engine sweep-driver benchmark: serial vs parallel fan-out of a
//! budget × topology grid (the fig5/fig6-style sweeps, parallelized).
//! Each grid point is a spec-driven `experiment::run` on the sequential
//! engine backend.
//!
//! Run: `cargo bench --bench engine_sweep` (append `-- --dry-run` for the
//! CI smoke variant: a tiny grid, no speedup assertions).
//!
//! BENCH NOTE (ISSUE 1 acceptance): on ≥ 4 cores the parallel sweep must
//! show > 1.5× speedup over the serial sweep; the assertion below
//! enforces it whenever the host has ≥ 4 hardware threads. On smaller
//! hosts the measured speedup is only printed.

use matcha::engine::{available_threads, sweep_parallel, sweep_serial};
use matcha::experiment::{self, Backend, ExperimentSpec, ProblemSpec, Strategy};
use matcha::graph::{self, Graph};
use matcha::rng::Rng;
use std::time::Instant;

struct Point {
    name: &'static str,
    graph: Graph,
    cb: f64,
}

fn grid(budgets: &[f64]) -> Vec<Point> {
    let mut rng = Rng::new(44);
    let bases: Vec<(&'static str, Graph)> = vec![
        ("fig1", graph::paper_figure1_graph()),
        ("ring12", graph::ring(12)),
        ("er16", graph::erdos_renyi_connected(16, 0.4, &mut rng)),
        ("grid3x4", graph::grid(3, 4)),
    ];
    let mut points = Vec::new();
    for (name, g) in bases {
        for &cb in budgets {
            points.push(Point { name, graph: g.clone(), cb });
        }
    }
    points
}

fn run_point(p: &Point, iters: usize) -> (f64, f64) {
    let spec = ExperimentSpec::on_graph(p.graph.clone())
        .strategy(Strategy::Matcha { budget: p.cb })
        .problem(ProblemSpec::Quadratic { dim: 24, hetero: 1.0, noise_std: 0.2, seed: Some(7) })
        .backend(Backend::EngineSequential)
        .lr(0.02)
        .iterations(iters)
        .record_every(iters.max(1))
        .seed(11)
        .sampler_seed(5);
    let r = experiment::run(&spec).expect("grid point run");
    (r.total_time, r.final_loss())
}

fn main() {
    let dry_run = std::env::args().any(|a| a == "--dry-run");
    let (budgets, iters): (&[f64], usize) = if dry_run {
        (&[0.5], 30)
    } else {
        (&[0.2, 0.4, 0.6, 0.8, 1.0], 1500)
    };
    let points = grid(budgets);
    let cores = available_threads();
    println!(
        "=== engine sweep driver: {} grid points × {iters} iters, {cores} hardware threads ===",
        points.len()
    );

    let t0 = Instant::now();
    let serial = sweep_serial(&points, |_i, p| run_point(p, iters));
    let serial_secs = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let parallel = sweep_parallel(&points, cores, |_i, p| run_point(p, iters));
    let parallel_secs = t1.elapsed().as_secs_f64();

    assert_eq!(
        serial, parallel,
        "parallel sweep must reproduce the serial results exactly"
    );

    let mut table = matcha::benchkit::Table::new(&["topology", "CB", "virtual time", "final loss"]);
    for (p, (time, loss)) in points.iter().zip(&serial) {
        table.row(&[
            p.name.to_string(),
            format!("{}", p.cb),
            format!("{time:.0}"),
            format!("{loss:.5}"),
        ]);
    }
    table.print();

    let speedup = serial_secs / parallel_secs.max(1e-9);
    println!(
        "\nserial: {serial_secs:.2}s, parallel ({cores} threads): {parallel_secs:.2}s, \
         speedup {speedup:.2}x"
    );
    if dry_run {
        println!("dry-run: skipping speedup assertion");
        return;
    }
    if cores >= 4 {
        assert!(
            speedup > 1.5,
            "BENCH NOTE violated: expected >1.5x sweep speedup on {cores} cores, got {speedup:.2}x"
        );
        println!("bench note: >1.5x speedup on ≥4 cores ✓");
    } else {
        println!("bench note: host has {cores} < 4 threads; speedup assertion skipped");
    }
}
