//! Hot-path micro-benchmarks for the §Perf pass (criterion substitute).
//!
//! Covers every L3 component that sits inside an optimization or training
//! loop: the Jacobi eigensolver (inner loop of the p-optimizer), the
//! capped-simplex projection, the plan stage (decompose + probabilities +
//! α), Misra–Gries decomposition, the simulator's gossip+SGD iteration,
//! and schedule sampling — plus the full spec→plan→run experiment
//! pipeline, so API-layer overhead stays visible. Numbers land in
//! EXPERIMENTS.md §Perf.
//!
//! The **state-arena mixing sweep** measures the gossip mix kernel over a
//! (workers × dim) grid under an allocation-counting global allocator:
//! both the plain arena path and the TopK-compressed path must perform
//! **zero** heap allocations per iteration (asserted — compression runs
//! off recycled pool scratch), and the sweep also times the pre-arena
//! per-message-clone behavior as the before/after record. The summary
//! records whether the SIMD row kernels were live (`simd`), so `ci.sh`
//! can run the sweep twice — default and `MATCHA_NO_SIMD=1` — and gate
//! the allocation counts on both. Results land in `BENCH_state.json`
//! (emitted in `--dry-run` too, so `ci.sh` smokes it).

use matcha::benchkit::bench_auto;
use matcha::budget::project_capped_simplex;
use matcha::experiment::{self, Backend, ExperimentSpec, Plan, ProblemSpec, Strategy};
use matcha::graph::{complete, erdos_renyi, paper_figure1_graph, ring};
use matcha::json::Json;
use matcha::linalg::{symmetric_eigen, Mat};
use matcha::matching::decompose;
use matcha::rng::Rng;
use matcha::sim::kernel::edge_diff_message;
use matcha::sim::{run_decentralized, Compression, QuadraticProblem};
use matcha::state::{simd_active, DeltaPool, MixKernel, StateMatrix};
use matcha::topology::TopologySampler;
use matcha::trace::{Counter, Hist, Observatory, TraceEvent, Tracer};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Allocation-counting wrapper over the system allocator — how the sweep
/// proves the arena mix hot path is allocation-free.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Emission through a sink-less [`Tracer`] must stay a single branch:
/// zero heap allocations per `emit`/`count`/`observe` (asserted) —
/// the property that lets tracing calls live unconditionally inside
/// every backend's hot loop. Returns allocs/emit for `BENCH_state.json`.
fn trace_disabled_allocs(iters: usize) -> f64 {
    let mut tracer = Tracer::disabled();
    tracer.emit(TraceEvent::RoundBarrier { k: 0 });
    let before = ALLOC_COUNT.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for k in 0..iters {
        tracer.set_now(k as f64);
        tracer.emit(TraceEvent::ComputeBegin { worker: k % 8, k });
        tracer.emit(TraceEvent::MixApplied { k, activated: 3 });
        tracer.count(Counter::MixRounds, 1);
        tracer.observe(Hist::QueueDepth, (k % 5) as f64);
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let emits = (iters * 4) as f64;
    let allocs = (ALLOC_COUNT.load(Ordering::Relaxed) - before) as f64 / emits;
    std::hint::black_box(tracer.registry.counter(Counter::MixRounds));
    println!("trace disabled: {allocs:.1} allocs/emit over {emits:.0} emits ({ns:.0} ns/iter)");
    assert!(
        allocs == 0.0,
        "disabled tracer emission must be allocation-free, saw {allocs} allocs/emit"
    );
    allocs
}

/// A disabled [`Observatory`] is one pointer-null branch per hook —
/// zero heap allocations per round of hook calls (asserted), the
/// property that lets every backend feed the convergence observatory
/// unconditionally from its hot loop. Returns allocs/iter for
/// `BENCH_state.json`.
fn observatory_disabled_allocs(iters: usize) -> f64 {
    let mut obs = Observatory::disabled();
    let activated = [0usize, 2];
    obs.on_round(&activated, &[]);
    let before = ALLOC_COUNT.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for k in 0..iters {
        obs.on_compute(k % 8, 1.0);
        obs.on_round(&activated, &[]);
        obs.on_stale_exchange(k % 8, (k + 1) % 8, k % 3);
        std::hint::black_box(obs.on_record(k, k as f64, 0.5, 0.1, 0.01));
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let allocs = (ALLOC_COUNT.load(Ordering::Relaxed) - before) as f64 / iters as f64;
    assert!(obs.snapshot().is_none() && obs.health().is_none());
    println!("observatory disabled: {allocs:.1} allocs/iter over {iters} iters ({ns:.0} ns/iter)");
    assert!(
        allocs == 0.0,
        "disabled observatory hooks must be allocation-free, saw {allocs} allocs/iter"
    );
    allocs
}

/// Mixing-throughput sweep over a (workers × dim) grid: arena kernel vs
/// the pre-arena per-message-clone fold, allocations-per-iteration and
/// elements/sec, written to `BENCH_state.json` along with the
/// disabled-tracer allocation assertion above.
fn state_mix_sweep(dry_run: bool) {
    println!("\n=== state arena: gossip mix throughput (workers x dim) ===");
    let grid: &[(usize, usize)] = if dry_run {
        &[(8, 50)]
    } else {
        &[(8, 50), (32, 200), (128, 500), (512, 1000)]
    };
    let iters = if dry_run { 50usize } else { 200 };
    let mut points = Vec::new();
    for &(m, dim) in grid {
        let d = decompose(&ring(m));
        let activated: Vec<usize> = (0..d.len()).collect();
        let edges: usize = activated.iter().map(|&j| d.matchings[j].edges().len()).sum();
        let mut xs = StateMatrix::init(7, m, dim);
        let mut rng = Rng::new(13);
        for w in 0..m {
            for x in xs.row_mut(w).iter_mut() {
                *x += 0.1 * rng.normal();
            }
        }
        let mut pool = DeltaPool::new(m, dim);
        let kernel = MixKernel::new(3, None);

        // Arena path: one warmup mix, then count allocations and time.
        kernel.apply(&mut xs, &d.matchings, &activated, 0.3, None, 0, &mut pool);
        let before = ALLOC_COUNT.load(Ordering::Relaxed);
        let t0 = Instant::now();
        for k in 0..iters {
            kernel.apply(&mut xs, &d.matchings, &activated, 0.3, None, k, &mut pool);
        }
        let arena_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let arena_allocs =
            (ALLOC_COUNT.load(Ordering::Relaxed) - before) as f64 / iters as f64;
        std::hint::black_box(xs.row(0));

        // Compressed path: the same fold through TopK sparsification.
        // The magnitude buffer is recycled pool scratch and the
        // threshold select uses `sort_unstable` (no merge-sort temp), so
        // compression must not reintroduce per-iteration allocations.
        let comp = Compression::TopK { frac: 0.25 };
        let ckernel = MixKernel::new(3, Some(&comp));
        ckernel.apply(&mut xs, &d.matchings, &activated, 0.3, None, 0, &mut pool);
        let before = ALLOC_COUNT.load(Ordering::Relaxed);
        let t0 = Instant::now();
        for k in 0..iters {
            ckernel.apply(&mut xs, &d.matchings, &activated, 0.3, None, k, &mut pool);
        }
        let comp_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let comp_allocs =
            (ALLOC_COUNT.load(Ordering::Relaxed) - before) as f64 / iters as f64;
        std::hint::black_box(xs.row(0));

        // Pre-arena baseline: the same fold, but every message clones
        // the two endpoint iterates (what the engine's actor messages and
        // the async runtime's snapshots used to do per exchange).
        let mut deltas: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; dim]).collect();
        let mut diff = vec![0.0; dim];
        let before = ALLOC_COUNT.load(Ordering::Relaxed);
        let t0 = Instant::now();
        for k in 0..iters {
            for dv in deltas.iter_mut() {
                dv.iter_mut().for_each(|v| *v = 0.0);
            }
            for &j in &activated {
                for &(u, v) in d.matchings[j].edges() {
                    let xu = xs.row(u).to_vec();
                    let xv = xs.row(v).to_vec();
                    edge_diff_message(&xu, &xv, &mut diff, None, 3, k, j, u, v);
                    for (a, &b) in deltas[u].iter_mut().zip(diff.iter()) {
                        *a += b;
                    }
                    for (a, &b) in deltas[v].iter_mut().zip(diff.iter()) {
                        *a -= b;
                    }
                }
            }
            for (w, dv) in deltas.iter().enumerate() {
                for (xi, &di) in xs.row_mut(w).iter_mut().zip(dv) {
                    *xi += 0.3 * di;
                }
            }
        }
        let clone_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        let clone_allocs =
            (ALLOC_COUNT.load(Ordering::Relaxed) - before) as f64 / iters as f64;
        std::hint::black_box(xs.row(0));

        // Elements touched per mix: both endpoint rows of every edge.
        let elements = (2 * edges * dim) as f64;
        let elements_per_sec = elements / (arena_ns / 1e9);
        let mix_ns_per_row = arena_ns / (2 * edges) as f64;
        println!(
            "state mix m={m:<4} d={dim:<5} edges/iter={edges:<4} \
             arena: {arena_allocs:.1} allocs/iter {arena_ns:>12.0} ns/iter \
             ({elements_per_sec:.3e} elem/s, {mix_ns_per_row:.0} ns/row)  \
             topk: {comp_allocs:.1} allocs/iter {comp_ns:>12.0} ns/iter  \
             clone-baseline: {clone_allocs:.1} allocs/iter {clone_ns:>12.0} ns/iter"
        );
        assert!(
            arena_allocs == 0.0,
            "arena gossip mix hot path must be allocation-free, saw {arena_allocs} allocs/iter"
        );
        assert!(
            comp_allocs == 0.0,
            "compressed (TopK) mix hot path must be allocation-free, saw {comp_allocs} allocs/iter"
        );
        assert!(
            clone_allocs > 0.0,
            "clone baseline should allocate per message (sanity check of the counter)"
        );
        points.push(Json::obj(vec![
            ("workers", Json::Num(m as f64)),
            ("dim", Json::Num(dim as f64)),
            ("edges_per_iter", Json::Num(edges as f64)),
            ("allocs_per_iter_arena", Json::Num(arena_allocs)),
            ("allocs_per_iter_compressed", Json::Num(comp_allocs)),
            ("allocs_per_iter_clone_baseline", Json::Num(clone_allocs)),
            ("ns_per_iter_arena", Json::Num(arena_ns)),
            ("ns_per_iter_compressed", Json::Num(comp_ns)),
            ("ns_per_iter_clone_baseline", Json::Num(clone_ns)),
            ("mix_ns_per_row", Json::Num(mix_ns_per_row)),
            ("elements_per_sec", Json::Num(elements_per_sec)),
        ]));
    }
    println!("\n=== trace: disabled-tracer emission overhead ===");
    let trace_allocs = trace_disabled_allocs(if dry_run { 10_000 } else { 1_000_000 });
    println!("\n=== observatory: disabled-hook overhead ===");
    let obs_allocs = observatory_disabled_allocs(if dry_run { 10_000 } else { 1_000_000 });
    let summary = Json::obj(vec![
        ("mode", Json::Str(if dry_run { "dry" } else { "full" }.into())),
        // Whether the SIMD row kernels were live for this run (machine-
        // and env-dependent: AVX2 detection gated by MATCHA_NO_SIMD).
        // Informational, never regression-gated.
        ("simd", Json::Bool(simd_active())),
        ("iters_per_point", Json::Num(iters as f64)),
        ("trace_disabled_allocs_per_emit", Json::Num(trace_allocs)),
        ("observatory_disabled_allocs_per_iter", Json::Num(obs_allocs)),
        ("grid", Json::Arr(points)),
    ]);
    std::fs::write("BENCH_state.json", summary.to_string()).expect("write BENCH_state.json");
    println!("wrote BENCH_state.json");
}

fn random_symmetric(n: usize, rng: &mut Rng) -> Mat {
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let v = rng.normal();
            a.set(i, j, v);
            a.set(j, i, v);
        }
    }
    a
}

/// The shared spec for the throughput sections: fig1 graph, MATCHA at
/// CB 0.5, quadratic workload.
fn throughput_spec(iters: usize, backend: Backend) -> ExperimentSpec {
    ExperimentSpec::new("fig1")
        .strategy(Strategy::Matcha { budget: 0.5 })
        .problem(ProblemSpec::Quadratic { dim: 50, hetero: 1.0, noise_std: 0.1, seed: Some(3) })
        .backend(backend)
        .iterations(iters)
        .record_every(1000)
        .sampler_seed(5)
}

fn main() {
    let mut rng = Rng::new(2024);

    // CI smoke mode (`ci.sh`): exercise one cheap target per section and
    // exit, so a bench-harness regression is caught without paying the
    // full calibrated run.
    let dry_run = std::env::args().any(|a| a == "--dry-run");
    if dry_run {
        let g8 = paper_figure1_graph();
        bench_auto("dry: misra_gries fig1", 20, || {
            std::hint::black_box(decompose(&g8));
        });
        bench_auto("dry: experiment sim 20 iters", 30, || {
            let spec = throughput_spec(20, Backend::SimReference);
            std::hint::black_box(experiment::run(&spec).unwrap());
        });
        bench_auto("dry: experiment engine 20 iters", 30, || {
            let spec = throughput_spec(20, Backend::EngineSequential);
            std::hint::black_box(experiment::run(&spec).unwrap());
        });
        state_mix_sweep(true);
        println!("dry-run complete");
        return;
    }

    println!("=== eigensolver (the p-optimizer's inner loop) ===");
    for n in [8, 16, 32, 64] {
        let a = random_symmetric(n, &mut rng);
        bench_auto(&format!("jacobi_eigen {n}x{n}"), 300, || {
            std::hint::black_box(symmetric_eigen(&a));
        });
    }

    println!("\n=== capped-simplex projection ===");
    for m in [6, 16, 64] {
        let y: Vec<f64> = (0..m).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        bench_auto(&format!("project_capped_simplex M={m}"), 100, || {
            std::hint::black_box(project_capped_simplex(&y, m as f64 * 0.4));
        });
    }

    println!("\n=== matching decomposition ===");
    let g8 = paper_figure1_graph();
    let g16 = erdos_renyi(16, 0.5, &mut Rng::new(1));
    let k32 = complete(32);
    bench_auto("misra_gries fig1 (8n/12e)", 150, || {
        std::hint::black_box(decompose(&g8));
    });
    bench_auto("misra_gries er16 (~60e)", 200, || {
        std::hint::black_box(decompose(&g16));
    });
    bench_auto("misra_gries K32 (496e)", 400, || {
        std::hint::black_box(decompose(&k32));
    });

    println!("\n=== plan stage (decompose + probabilities + alpha) ===");
    bench_auto("plan fig1 matcha cb=0.5", 1000, || {
        std::hint::black_box(
            Plan::for_graph(g8.clone(), Strategy::Matcha { budget: 0.5 }).unwrap(),
        );
    });

    // One plan reused by the runner-isolation benches below (planning
    // cost measured separately above, so these time the runners alone).
    let plan = Plan::for_graph(g8.clone(), Strategy::Matcha { budget: 0.5 }).unwrap();
    let spec = throughput_spec(100, Backend::SimReference);
    let cfg = plan.run_config(&spec).unwrap();
    let p = {
        let mut r = Rng::new(3);
        QuadraticProblem::generate(8, 50, 1.0, 0.1, &mut r)
    };

    println!("\n=== simulator iteration throughput ===");
    bench_auto("sim 100 iters m=8 d=50 (gossip+sgd)", 1500, || {
        let mut s = plan.sampler(5);
        std::hint::black_box(run_decentralized(&p, &plan.decomposition.matchings, &mut s, &cfg));
    });

    println!("\n=== engine iteration throughput (event-queue overhead vs sim) ===");
    bench_auto("engine 100 iters m=8 d=50 sequential", 1500, || {
        let mut s = plan.sampler(5);
        let engine_cfg = matcha::engine::EngineConfig { run: cfg.clone(), threads: 1 };
        std::hint::black_box(matcha::engine::run_engine_analytic(
            &p,
            &plan.decomposition.matchings,
            &mut s,
            &engine_cfg,
        ));
    });

    println!("\n=== full experiment pipeline (spec -> plan -> run) ===");
    bench_auto("experiment::run sim 100 iters", 1500, || {
        let spec = throughput_spec(100, Backend::SimReference);
        std::hint::black_box(experiment::run(&spec).unwrap());
    });

    println!("\n=== schedule generation (apriori cost) ===");
    bench_auto("schedule 10k rounds", 400, || {
        std::hint::black_box(plan.schedule(10_000, 5));
    });
    let mut s = plan.sampler(5);
    bench_auto("sampler round", 50, || {
        std::hint::black_box(s.round(0));
    });

    state_mix_sweep(false);
}
