//! Hot-path micro-benchmarks for the §Perf pass (criterion substitute).
//!
//! Covers every L3 component that sits inside an optimization or training
//! loop: the Jacobi eigensolver (inner loop of the p-optimizer), the
//! capped-simplex projection, the full budget optimizer, Misra–Gries
//! decomposition, the simulator's gossip+SGD iteration, and schedule
//! sampling. Numbers land in EXPERIMENTS.md §Perf.

use matcha::benchkit::bench_auto;
use matcha::budget::{optimize_activation_probabilities, project_capped_simplex};
use matcha::graph::{complete, erdos_renyi, paper_figure1_graph};
use matcha::linalg::{symmetric_eigen, Mat};
use matcha::matching::decompose;
use matcha::mixing::optimize_alpha;
use matcha::rng::Rng;
use matcha::sim::{run_decentralized, QuadraticProblem, RunConfig};
use matcha::topology::{MatchaSampler, Schedule, TopologySampler};

fn random_symmetric(n: usize, rng: &mut Rng) -> Mat {
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let v = rng.normal();
            a.set(i, j, v);
            a.set(j, i, v);
        }
    }
    a
}

fn main() {
    let mut rng = Rng::new(2024);

    // CI smoke mode (`ci.sh`): exercise one cheap target per section and
    // exit, so a bench-harness regression is caught without paying the
    // full calibrated run.
    let dry_run = std::env::args().any(|a| a == "--dry-run");
    if dry_run {
        let g8 = paper_figure1_graph();
        let d8 = decompose(&g8);
        bench_auto("dry: misra_gries fig1", 20, || {
            std::hint::black_box(decompose(&g8));
        });
        let p = {
            let mut r = Rng::new(3);
            QuadraticProblem::generate(8, 20, 1.0, 0.1, &mut r)
        };
        let probs = optimize_activation_probabilities(&d8, 0.5);
        let mix = optimize_alpha(&d8, &probs.probabilities);
        bench_auto("dry: sim 20 iters", 30, || {
            let mut s = MatchaSampler::new(probs.probabilities.clone(), 5);
            let cfg = RunConfig {
                iterations: 20,
                record_every: 1000,
                alpha: mix.alpha,
                ..RunConfig::default()
            };
            std::hint::black_box(run_decentralized(&p, &d8.matchings, &mut s, &cfg));
        });
        bench_auto("dry: engine 20 iters", 30, || {
            let mut s = MatchaSampler::new(probs.probabilities.clone(), 5);
            let cfg = matcha::engine::EngineConfig {
                run: RunConfig {
                    iterations: 20,
                    record_every: 1000,
                    alpha: mix.alpha,
                    ..RunConfig::default()
                },
                threads: 1,
            };
            std::hint::black_box(matcha::engine::run_engine_analytic(
                &p,
                &d8.matchings,
                &mut s,
                &cfg,
            ));
        });
        println!("dry-run complete");
        return;
    }

    println!("=== eigensolver (the p-optimizer's inner loop) ===");
    for n in [8, 16, 32, 64] {
        let a = random_symmetric(n, &mut rng);
        bench_auto(&format!("jacobi_eigen {n}x{n}"), 300, || {
            std::hint::black_box(symmetric_eigen(&a));
        });
    }

    println!("\n=== capped-simplex projection ===");
    for m in [6, 16, 64] {
        let y: Vec<f64> = (0..m).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        bench_auto(&format!("project_capped_simplex M={m}"), 100, || {
            std::hint::black_box(project_capped_simplex(&y, m as f64 * 0.4));
        });
    }

    println!("\n=== matching decomposition ===");
    let g8 = paper_figure1_graph();
    let g16 = erdos_renyi(16, 0.5, &mut Rng::new(1));
    let k32 = complete(32);
    bench_auto("misra_gries fig1 (8n/12e)", 150, || {
        std::hint::black_box(decompose(&g8));
    });
    bench_auto("misra_gries er16 (~60e)", 200, || {
        std::hint::black_box(decompose(&g16));
    });
    bench_auto("misra_gries K32 (496e)", 400, || {
        std::hint::black_box(decompose(&k32));
    });

    println!("\n=== full budget + alpha optimization (one-time setup cost) ===");
    let d8 = decompose(&g8);
    bench_auto("optimize p+alpha fig1 cb=0.5", 1000, || {
        let p = optimize_activation_probabilities(&d8, 0.5);
        std::hint::black_box(optimize_alpha(&d8, &p.probabilities));
    });

    println!("\n=== simulator iteration throughput ===");
    let p = {
        let mut r = Rng::new(3);
        QuadraticProblem::generate(8, 50, 1.0, 0.1, &mut r)
    };
    let probs = optimize_activation_probabilities(&d8, 0.5);
    let mix = optimize_alpha(&d8, &probs.probabilities);
    bench_auto("sim 100 iters m=8 d=50 (gossip+sgd)", 1500, || {
        let mut s = MatchaSampler::new(probs.probabilities.clone(), 5);
        let cfg = RunConfig {
            iterations: 100,
            record_every: 1000,
            alpha: mix.alpha,
            ..RunConfig::default()
        };
        std::hint::black_box(run_decentralized(&p, &d8.matchings, &mut s, &cfg));
    });

    println!("\n=== engine iteration throughput (event-queue overhead vs sim) ===");
    bench_auto("engine 100 iters m=8 d=50 sequential", 1500, || {
        let mut s = MatchaSampler::new(probs.probabilities.clone(), 5);
        let cfg = matcha::engine::EngineConfig {
            run: RunConfig {
                iterations: 100,
                record_every: 1000,
                alpha: mix.alpha,
                ..RunConfig::default()
            },
            threads: 1,
        };
        std::hint::black_box(matcha::engine::run_engine_analytic(
            &p,
            &d8.matchings,
            &mut s,
            &cfg,
        ));
    });

    println!("\n=== schedule generation (apriori cost) ===");
    bench_auto("schedule 10k rounds", 400, || {
        let mut s = MatchaSampler::new(probs.probabilities.clone(), 5);
        std::hint::black_box(Schedule::generate(&mut s, mix.alpha, d8.len(), 10_000));
    });
    let mut s = MatchaSampler::new(probs.probabilities.clone(), 5);
    bench_auto("sampler round", 50, || {
        std::hint::black_box(s.round(0));
    });
}
