//! Hot-path micro-benchmarks for the §Perf pass (criterion substitute).
//!
//! Covers every L3 component that sits inside an optimization or training
//! loop: the Jacobi eigensolver (inner loop of the p-optimizer), the
//! capped-simplex projection, the plan stage (decompose + probabilities +
//! α), Misra–Gries decomposition, the simulator's gossip+SGD iteration,
//! and schedule sampling — plus the full spec→plan→run experiment
//! pipeline, so API-layer overhead stays visible. Numbers land in
//! EXPERIMENTS.md §Perf.

use matcha::benchkit::bench_auto;
use matcha::budget::project_capped_simplex;
use matcha::experiment::{self, Backend, ExperimentSpec, Plan, ProblemSpec, Strategy};
use matcha::graph::{complete, erdos_renyi, paper_figure1_graph};
use matcha::linalg::{symmetric_eigen, Mat};
use matcha::matching::decompose;
use matcha::rng::Rng;
use matcha::sim::{run_decentralized, QuadraticProblem};
use matcha::topology::TopologySampler;

fn random_symmetric(n: usize, rng: &mut Rng) -> Mat {
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let v = rng.normal();
            a.set(i, j, v);
            a.set(j, i, v);
        }
    }
    a
}

/// The shared spec for the throughput sections: fig1 graph, MATCHA at
/// CB 0.5, quadratic workload.
fn throughput_spec(iters: usize, backend: Backend) -> ExperimentSpec {
    ExperimentSpec::new("fig1")
        .strategy(Strategy::Matcha { budget: 0.5 })
        .problem(ProblemSpec::Quadratic { dim: 50, hetero: 1.0, noise_std: 0.1, seed: Some(3) })
        .backend(backend)
        .iterations(iters)
        .record_every(1000)
        .sampler_seed(5)
}

fn main() {
    let mut rng = Rng::new(2024);

    // CI smoke mode (`ci.sh`): exercise one cheap target per section and
    // exit, so a bench-harness regression is caught without paying the
    // full calibrated run.
    let dry_run = std::env::args().any(|a| a == "--dry-run");
    if dry_run {
        let g8 = paper_figure1_graph();
        bench_auto("dry: misra_gries fig1", 20, || {
            std::hint::black_box(decompose(&g8));
        });
        bench_auto("dry: experiment sim 20 iters", 30, || {
            let spec = throughput_spec(20, Backend::SimReference);
            std::hint::black_box(experiment::run(&spec).unwrap());
        });
        bench_auto("dry: experiment engine 20 iters", 30, || {
            let spec = throughput_spec(20, Backend::EngineSequential);
            std::hint::black_box(experiment::run(&spec).unwrap());
        });
        println!("dry-run complete");
        return;
    }

    println!("=== eigensolver (the p-optimizer's inner loop) ===");
    for n in [8, 16, 32, 64] {
        let a = random_symmetric(n, &mut rng);
        bench_auto(&format!("jacobi_eigen {n}x{n}"), 300, || {
            std::hint::black_box(symmetric_eigen(&a));
        });
    }

    println!("\n=== capped-simplex projection ===");
    for m in [6, 16, 64] {
        let y: Vec<f64> = (0..m).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        bench_auto(&format!("project_capped_simplex M={m}"), 100, || {
            std::hint::black_box(project_capped_simplex(&y, m as f64 * 0.4));
        });
    }

    println!("\n=== matching decomposition ===");
    let g8 = paper_figure1_graph();
    let g16 = erdos_renyi(16, 0.5, &mut Rng::new(1));
    let k32 = complete(32);
    bench_auto("misra_gries fig1 (8n/12e)", 150, || {
        std::hint::black_box(decompose(&g8));
    });
    bench_auto("misra_gries er16 (~60e)", 200, || {
        std::hint::black_box(decompose(&g16));
    });
    bench_auto("misra_gries K32 (496e)", 400, || {
        std::hint::black_box(decompose(&k32));
    });

    println!("\n=== plan stage (decompose + probabilities + alpha) ===");
    bench_auto("plan fig1 matcha cb=0.5", 1000, || {
        std::hint::black_box(
            Plan::for_graph(g8.clone(), Strategy::Matcha { budget: 0.5 }).unwrap(),
        );
    });

    // One plan reused by the runner-isolation benches below (planning
    // cost measured separately above, so these time the runners alone).
    let plan = Plan::for_graph(g8.clone(), Strategy::Matcha { budget: 0.5 }).unwrap();
    let spec = throughput_spec(100, Backend::SimReference);
    let cfg = plan.run_config(&spec).unwrap();
    let p = {
        let mut r = Rng::new(3);
        QuadraticProblem::generate(8, 50, 1.0, 0.1, &mut r)
    };

    println!("\n=== simulator iteration throughput ===");
    bench_auto("sim 100 iters m=8 d=50 (gossip+sgd)", 1500, || {
        let mut s = plan.sampler(5);
        std::hint::black_box(run_decentralized(&p, &plan.decomposition.matchings, &mut s, &cfg));
    });

    println!("\n=== engine iteration throughput (event-queue overhead vs sim) ===");
    bench_auto("engine 100 iters m=8 d=50 sequential", 1500, || {
        let mut s = plan.sampler(5);
        let engine_cfg = matcha::engine::EngineConfig { run: cfg.clone(), threads: 1 };
        std::hint::black_box(matcha::engine::run_engine_analytic(
            &p,
            &plan.decomposition.matchings,
            &mut s,
            &engine_cfg,
        ));
    });

    println!("\n=== full experiment pipeline (spec -> plan -> run) ===");
    bench_auto("experiment::run sim 100 iters", 1500, || {
        let spec = throughput_spec(100, Backend::SimReference);
        std::hint::black_box(experiment::run(&spec).unwrap());
    });

    println!("\n=== schedule generation (apriori cost) ===");
    bench_auto("schedule 10k rounds", 400, || {
        std::hint::black_box(plan.schedule(10_000, 5));
    });
    let mut s = plan.sampler(5);
    bench_auto("sampler round", 50, || {
        std::hint::black_box(s.round(0));
    });
}
