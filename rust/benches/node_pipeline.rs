//! Shard-node pipeline bench: what command pipelining buys back from the
//! network round-trip.
//!
//! The in-process cluster driver is strictly request/reply — every phase
//! pays a full round-trip per shard, twice per mixing iteration. The
//! remote coordinator (`matcha::node`) streams commands ahead of the
//! replies instead, bounded by `RemoteOptions::window`. This bench runs
//! the same MATCHA schedule against real shard-node daemons on localhost
//! at increasing window depths, with the in-process TCP cluster as the
//! unpipelined baseline, and asserts the window never changes the
//! result — pipelining is a latency optimization, not a semantic one.
//!
//! Run: `cargo bench --bench node_pipeline` (append `-- --dry-run` for
//! the CI smoke variant: tiny runs, no assertions). Emits
//! `BENCH_node.json` either way.

use matcha::cluster::{ClusterResult, TransportKind};
use matcha::experiment::{self, Backend, ExperimentResult, ExperimentSpec, ProblemSpec, Strategy};
use matcha::json::Json;
use matcha::node::{run_daemon, run_remote, DaemonOptions, RemoteOptions};
use std::net::TcpListener;
use std::time::Instant;

fn base_spec(iters: usize, backend: Backend) -> ExperimentSpec {
    ExperimentSpec::new("er:16:4:7")
        .strategy(Strategy::Matcha { budget: 0.5 })
        .problem(ProblemSpec::Quadratic { dim: 64, hetero: 1.0, noise_std: 0.2, seed: Some(7) })
        .backend(backend)
        .lr(0.02)
        .iterations(iters)
        .record_every(iters.max(1))
        .seed(11)
        .sampler_seed(5)
}

/// Serve a default shard-node daemon on an ephemeral localhost port from
/// a background thread; return its address. `once: false`, so one daemon
/// serves every run of the sweep back to back.
fn spawn_daemon() -> String {
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind daemon port");
    let addr = listener.local_addr().expect("daemon addr").to_string();
    let opts = DaemonOptions::default();
    std::thread::spawn(move || run_daemon(listener, &opts));
    addr
}

/// Run the spec `repeats` times through the unified runner; return the
/// (identical) result and the fastest wall-clock in seconds.
fn timed(spec: &ExperimentSpec, repeats: usize) -> (ExperimentResult, f64) {
    let mut best = f64::INFINITY;
    let mut result = None;
    for _ in 0..repeats {
        let t0 = Instant::now();
        let r = experiment::run(spec).expect("bench run");
        best = best.min(t0.elapsed().as_secs_f64());
        result = Some(r);
    }
    (result.expect("at least one repeat"), best)
}

/// Run the remote spec `repeats` times at one pipeline window depth.
fn timed_remote(spec: &ExperimentSpec, window: usize, repeats: usize) -> (ClusterResult, f64) {
    let opts = RemoteOptions { window, ..RemoteOptions::default() };
    let mut best = f64::INFINITY;
    let mut result = None;
    for _ in 0..repeats {
        let t0 = Instant::now();
        let r = run_remote(spec, &opts).expect("remote bench run");
        best = best.min(t0.elapsed().as_secs_f64());
        result = Some(r);
    }
    (result.expect("at least one repeat"), best)
}

fn main() {
    let dry_run = std::env::args().any(|a| a == "--dry-run");
    let (iters, repeats) = if dry_run { (20, 1) } else { (300, 3) };
    let shards = 2usize;
    let dim = 64usize;
    let windows = [1usize, 2, 4, 8];
    println!("=== shard-node pipeline: 16 workers over {shards} daemons, {iters} iters ===");

    // Baseline: the in-process cluster backend over real localhost TCP —
    // the same wire, strictly request/reply.
    let (tcp, tcp_wall) = timed(
        &base_spec(iters, Backend::Cluster { shards, transport: TransportKind::Tcp }),
        repeats,
    );

    let addrs: Vec<String> = (0..shards).map(|_| spawn_daemon()).collect();
    let spec = base_spec(
        iters,
        Backend::Cluster { shards, transport: TransportKind::Remote { addrs } },
    );
    let runs: Vec<(usize, ClusterResult, f64)> = windows
        .iter()
        .map(|&w| {
            let (r, wall) = timed_remote(&spec, w, repeats);
            (w, r, wall)
        })
        .collect();

    let bytes_per_iter = runs[0].1.stats.total_bytes() as f64 / iters as f64;
    let frames_per_iter = runs[0].1.stats.total_frames() as f64 / iters as f64;
    // Payload the MixLocal suppression kept off the wire: rows whose
    // peer lives on the receiving shard. With 8 workers per shard the
    // er:16 schedule activates plenty of intra-shard edges, so this is
    // strictly positive (asserted below) and `bytes_per_iter` above is
    // strictly smaller than a ship-everything protocol would pay.
    let suppressed_per_iter = runs[0].1.stats.suppressed_bytes() as f64 / iters as f64;

    let mut table =
        matcha::benchkit::Table::new(&["mode", "wall (s)", "iters/s", "final loss"]);
    table.row(&[
        "cluster tcp (request/reply)".to_string(),
        format!("{tcp_wall:.3}"),
        format!("{:.1}", iters as f64 / tcp_wall.max(1e-9)),
        format!("{:.5}", tcp.final_loss()),
    ]);
    for (w, r, wall) in &runs {
        table.row(&[
            format!("shard-node window={w}"),
            format!("{wall:.3}"),
            format!("{:.1}", iters as f64 / wall.max(1e-9)),
            format!("{:.5}", r.run.metrics.last("loss_vs_iter").unwrap_or(f64::NAN)),
        ]);
    }
    table.print();
    println!(
        "bytes/iter on the wire: {bytes_per_iter:.0} ({frames_per_iter:.1} frames, \
         {suppressed_per_iter:.0} bytes/iter suppressed intra-shard)"
    );

    // Telemetry overhead: the same remote schedule through the unified
    // runner, with daemon telemetry harvested into a merged Chrome
    // export vs fully untraced. The pulls ride sync barriers the
    // pipeline already pays for, so this should stay near 1.0x.
    let trace_path = std::env::temp_dir().join("matcha_bench_node_trace.json");
    let mut traced_spec = spec.clone();
    traced_spec.trace = Some(experiment::TraceSpec {
        path: trace_path.to_string_lossy().into_owned(),
        format: matcha::trace::TraceFormat::Chrome,
        capacity: 1 << 17,
        telemetry: true,
        telemetry_capacity: 1 << 17,
    });
    let (untraced, untraced_wall) = timed(&spec, repeats);
    let (traced, traced_wall) = timed(&traced_spec, repeats);
    let telemetry_overhead = traced_wall / untraced_wall.max(1e-9);
    std::fs::remove_file(&trace_path).ok();
    println!(
        "telemetry overhead: {telemetry_overhead:.3}x \
         (traced {traced_wall:.3}s vs untraced {untraced_wall:.3}s)"
    );

    let mut summary = vec![
        ("mode".to_string(), Json::Str(if dry_run { "dry" } else { "full" }.into())),
        ("workers".to_string(), Json::Num(16.0)),
        ("shards".to_string(), Json::Num(shards as f64)),
        ("iterations".to_string(), Json::Num(iters as f64)),
        ("dim".to_string(), Json::Num(dim as f64)),
        ("bytes_per_iter".to_string(), Json::Num(bytes_per_iter)),
        ("frames_per_iter".to_string(), Json::Num(frames_per_iter)),
        ("suppressed_bytes_per_iter".to_string(), Json::Num(suppressed_per_iter)),
        ("wall_tcp_cluster_s".to_string(), Json::Num(tcp_wall)),
        (
            "pipeline_speedup_w8".to_string(),
            Json::Num(runs[0].2 / runs[runs.len() - 1].2.max(1e-9)),
        ),
        // Wall-clock ratio, machine-dependent: recorded in the
        // trajectory but deliberately not a gated regression key.
        ("telemetry_overhead".to_string(), Json::Num(telemetry_overhead)),
    ];
    for (w, _, wall) in &runs {
        summary.push((format!("wall_window_{w}_s"), Json::Num(*wall)));
    }
    let json = Json::Obj(summary.into_iter().collect());
    std::fs::write("BENCH_node.json", json.to_string()).expect("write BENCH_node.json");
    println!("\nwrote BENCH_node.json");

    if dry_run {
        println!("dry-run: skipping assertions");
        return;
    }
    assert_eq!(
        traced.final_mean, untraced.final_mean,
        "telemetry harvesting must never change results"
    );
    assert!(
        runs[0].1.stats.suppressed_bytes() > 0,
        "8 workers per shard must activate intra-shard edges whose rows are suppressed"
    );
    for (w, r, _) in &runs {
        assert_eq!(
            r.run.final_mean, tcp.final_mean,
            "window={w} must match the in-process TCP cluster bit-for-bit"
        );
        assert_eq!(
            r.stats.total_bytes(),
            runs[0].1.stats.total_bytes(),
            "window={w} must put identical bytes on the wire"
        );
        assert_eq!(
            r.stats.suppressed_bytes(),
            runs[0].1.stats.suppressed_bytes(),
            "window={w} must suppress the same intra-shard payload"
        );
    }
}
