//! Figure 1: per-node communication time, vanilla DecenSGD vs MATCHA at
//! CB = 0.5, on the 8-node base graph.
//!
//! Paper claim to reproduce: the degree-1 node (4) keeps its
//! communication time (its link (0,4) is critical), while the degree-5
//! busiest node (1) is cut to ~half. The activation probabilities come
//! from the `experiment` plan stage. Plus benchkit timings of the
//! schedule-construction hot path.

use matcha::benchkit::{bench_auto, Table};
use matcha::experiment::{Plan, Strategy};
use matcha::graph::{expected_node_comm_time, paper_figure1_graph};
use matcha::matching::decompose;

fn main() {
    let g = paper_figure1_graph();
    let cb = 0.5;
    let plan = Plan::for_graph(g.clone(), Strategy::Matcha { budget: cb }).unwrap();
    let matchings = &plan.decomposition.matchings;

    let vanilla =
        expected_node_comm_time(g.num_nodes(), matchings, &vec![1.0; plan.decomposition.len()]);
    let matcha = expected_node_comm_time(g.num_nodes(), matchings, &plan.probabilities);
    let deg = g.degrees();

    println!("=== Figure 1: per-node expected communication time (units/iter) ===");
    let mut t = Table::new(&["node", "degree", "vanilla", "matcha CB=0.5", "reduction"]);
    for i in 0..g.num_nodes() {
        t.row(&[
            i.to_string(),
            deg[i].to_string(),
            format!("{:.2}", vanilla[i]),
            format!("{:.2}", matcha[i]),
            format!("{:.0}%", 100.0 * (1.0 - matcha[i] / vanilla[i].max(1e-12))),
        ]);
    }
    t.print();

    // Paper's qualitative checks, asserted so the bench doubles as a test.
    let busiest = 1usize;
    let leaf = 4usize;
    assert!(
        matcha[busiest] <= 0.6 * vanilla[busiest],
        "busiest node not throttled: {} vs {}",
        matcha[busiest],
        vanilla[busiest]
    );
    // The leaf's budget share depends on which other edges share its
    // matching (the Δ=5 compacted decomposition groups (0,4) with edges
    // at busier nodes, so its probability lands ≈0.78 instead of ≈0.91
    // as in the Δ+1 decomposition). Either way it keeps far more than
    // the 50% global budget — the paper's qualitative point.
    assert!(
        matcha[leaf] >= 0.7 * vanilla[leaf],
        "critical leaf lost its communication: {} vs {}",
        matcha[leaf],
        vanilla[leaf]
    );
    println!(
        "\nchecks: busiest node reduced {:.0}%, critical leaf kept {:.0}% — matches Fig 1.",
        100.0 * (1.0 - matcha[busiest] / vanilla[busiest]),
        100.0 * matcha[leaf] / vanilla[leaf]
    );

    println!("\n=== hot-path timings ===");
    bench_auto("misra_gries_decompose(fig1)", 200, || {
        std::hint::black_box(decompose(&g));
    });
    bench_auto("plan(fig1, matcha cb=0.5)", 400, || {
        std::hint::black_box(Plan::for_graph(g.clone(), Strategy::Matcha { budget: 0.5 }).unwrap());
    });
}
