//! Cluster transport bench: what the wire actually costs.
//!
//! Runs the same MATCHA schedule through the cluster backend over both
//! transports and reports (a) bytes-on-wire per iteration — the number
//! the per-link byte accounting exists for — and (b) loopback-vs-TCP
//! wall-clock throughput, with the in-process actors backend as the
//! no-serialization baseline. The wire-clock conversion puts the
//! observed traffic on the same virtual-unit scale as the schedule's
//! simulated communication time.
//!
//! Run: `cargo bench --bench cluster_transport` (append `-- --dry-run`
//! for the CI smoke variant: tiny runs, no assertions). Emits
//! `BENCH_cluster.json` either way.

use matcha::cluster::{TransportKind, WireClock};
use matcha::experiment::{self, Backend, ExperimentResult, ExperimentSpec, ProblemSpec, Strategy};
use matcha::json::Json;
use std::time::Instant;

fn base_spec(iters: usize, backend: Backend) -> ExperimentSpec {
    ExperimentSpec::new("er:16:4:7")
        .strategy(Strategy::Matcha { budget: 0.5 })
        .problem(ProblemSpec::Quadratic { dim: 64, hetero: 1.0, noise_std: 0.2, seed: Some(7) })
        .backend(backend)
        .lr(0.02)
        .iterations(iters)
        .record_every(iters.max(1))
        .seed(11)
        .sampler_seed(5)
}

/// Run the spec `repeats` times; return the (identical) result and the
/// fastest wall-clock in seconds.
fn timed(spec: &ExperimentSpec, repeats: usize) -> (ExperimentResult, f64) {
    let mut best = f64::INFINITY;
    let mut result = None;
    for _ in 0..repeats {
        let t0 = Instant::now();
        let r = experiment::run(spec).expect("bench run");
        best = best.min(t0.elapsed().as_secs_f64());
        result = Some(r);
    }
    (result.expect("at least one repeat"), best)
}

fn main() {
    let dry_run = std::env::args().any(|a| a == "--dry-run");
    let (iters, repeats) = if dry_run { (20, 1) } else { (300, 3) };
    let shards = 4usize;
    let dim = 64usize;
    println!("=== cluster transports: 16 workers over {shards} shards, {iters} iters ===");

    let (actors, actors_wall) =
        timed(&base_spec(iters, Backend::EngineActors { threads: shards }), repeats);
    let (loopback, loopback_wall) = timed(
        &base_spec(
            iters,
            Backend::Cluster { shards, transport: TransportKind::Loopback },
        ),
        repeats,
    );
    let (tcp, tcp_wall) = timed(
        &base_spec(iters, Backend::Cluster { shards, transport: TransportKind::Tcp }),
        repeats,
    );

    let lb_stats = loopback.cluster_stats.as_ref().expect("loopback stats");
    let tcp_stats = tcp.cluster_stats.as_ref().expect("tcp stats");
    let bytes_per_iter = lb_stats.total_bytes() as f64 / iters as f64;
    let frames_per_iter = lb_stats.total_frames() as f64 / iters as f64;
    // One model row per link activation at unit link time — the delay
    // models' scale for the wire clock.
    let wire_units = lb_stats.wire_units(WireClock::per_row(dim, 1.0));

    let mut table = matcha::benchkit::Table::new(&[
        "mode",
        "wall (s)",
        "iters/s",
        "bytes/iter",
        "final loss",
    ]);
    let rows: [(&str, f64, Option<f64>, &ExperimentResult); 3] = [
        ("actors (in-process)", actors_wall, None, &actors),
        ("cluster loopback", loopback_wall, Some(bytes_per_iter), &loopback),
        ("cluster tcp", tcp_wall, Some(bytes_per_iter), &tcp),
    ];
    for (name, wall, bytes, res) in rows {
        table.row(&[
            name.to_string(),
            format!("{wall:.3}"),
            format!("{:.1}", iters as f64 / wall.max(1e-9)),
            bytes.map_or("-".to_string(), |b| format!("{b:.0}")),
            format!("{:.5}", res.final_loss()),
        ]);
    }
    table.print();
    println!(
        "wire clock: {wire_units:.1} virtual units of traffic vs {:.1} simulated comm units",
        loopback.total_comm_units
    );

    let summary = Json::obj(vec![
        ("mode", Json::Str(if dry_run { "dry" } else { "full" }.into())),
        ("workers", Json::Num(16.0)),
        ("shards", Json::Num(shards as f64)),
        ("iterations", Json::Num(iters as f64)),
        ("dim", Json::Num(dim as f64)),
        ("bytes_per_iter", Json::Num(bytes_per_iter)),
        ("frames_per_iter", Json::Num(frames_per_iter)),
        ("wire_units", Json::Num(wire_units)),
        ("simulated_comm_units", Json::Num(loopback.total_comm_units)),
        ("wall_actors_s", Json::Num(actors_wall)),
        ("wall_loopback_s", Json::Num(loopback_wall)),
        ("wall_tcp_s", Json::Num(tcp_wall)),
        (
            "loopback_iters_per_s",
            Json::Num(iters as f64 / loopback_wall.max(1e-9)),
        ),
        ("tcp_iters_per_s", Json::Num(iters as f64 / tcp_wall.max(1e-9))),
        (
            "tcp_vs_loopback_slowdown",
            Json::Num(tcp_wall / loopback_wall.max(1e-9)),
        ),
    ]);
    std::fs::write("BENCH_cluster.json", summary.to_string()).expect("write BENCH_cluster.json");
    println!("\nwrote BENCH_cluster.json");

    if dry_run {
        println!("dry-run: skipping assertions");
        return;
    }
    assert_eq!(
        loopback.final_mean, actors.final_mean,
        "loopback cluster must match the actors backend bit-for-bit"
    );
    assert_eq!(
        tcp.final_mean, loopback.final_mean,
        "tcp cluster must match loopback bit-for-bit"
    );
    assert_eq!(
        lb_stats.total_bytes(),
        tcp_stats.total_bytes(),
        "identical schedule must put identical bytes on either transport"
    );
    assert!(bytes_per_iter > 0.0, "byte accounting must observe traffic");
}
