//! Figure 5 (+ the §5 "effective degree" observation): 16-node topologies
//! of increasing density — MATCHA picks a budget that pins the *expected
//! activated degree* to ≈ 4, so its per-iteration communication stays flat
//! while vanilla's grows with Δ; time-to-target then favors MATCHA more
//! the denser the base graph.
//!
//! Budgets per topology follow the paper: CB = 0.75/0.4/0.3 for
//! Δ = 6/10/8(ER) — all chosen so the effective max degree ≈ 4.
//!
//! The per-topology runs are independent, so they fan out across cores
//! via the engine's sweep driver (`engine::sweep_parallel`); each point
//! is a pair of spec-driven `experiment::run` calls (seeds pinned to the
//! historical values, so the trajectories are unchanged).

use matcha::benchkit::Table;
use matcha::engine::{available_threads, sweep_parallel};
use matcha::experiment::{self, ExperimentSpec, NoopObserver, ProblemSpec, Strategy};
use matcha::graph::{expected_node_degree, paper_figure9_topologies, Graph};

struct PointResult {
    name: String,
    base_degree: usize,
    cb: f64,
    eff_max: f64,
    van_time: f64,
    matcha_time: f64,
    van_ttt: Option<f64>,
    matcha_ttt: Option<f64>,
}

fn spec(g: &Graph, strategy: Strategy, iters: usize) -> ExperimentSpec {
    ExperimentSpec::on_graph(g.clone())
        .strategy(strategy)
        .problem(ProblemSpec::Logistic { non_iid: 0.6, separation: 1.5, seed: Some(40) })
        .lr(0.1)
        .iterations(iters)
        .record_every(25)
        .compute_units(0.5)
        .seed(4)
        .sampler_seed(9)
}

fn main() {
    let topologies = paper_figure9_topologies();
    let budgets = [0.75, 0.4, 0.3]; // paper's choices per density
    let iters = 2500;

    println!("=== Fig 5 / Fig 9: 16-node topologies, effective-degree control ===");
    let points: Vec<_> = topologies.iter().zip(&budgets).collect();
    let results = sweep_parallel(&points, available_threads(), |_i, ((name, g), cb)| {
        let cb = **cb;
        let mspec = spec(g, Strategy::Matcha { budget: cb }, iters);
        let plan = experiment::plan(&mspec).expect("matcha plan");

        // §5 claim: expected activated degree ≈ 4 under the chosen CB.
        let eff = expected_node_degree(
            g.num_nodes(),
            &plan.decomposition.matchings,
            &plan.probabilities,
        );
        let eff_max = eff.iter().cloned().fold(0.0f64, f64::max);

        let vres = experiment::run(&spec(g, Strategy::Vanilla, iters)).expect("vanilla run");
        let mres = experiment::run_planned(&mspec, &plan, &mut NoopObserver).expect("matcha run");

        // Adaptive target: 5% above the best loss either run reaches
        // (the paper's fixed "loss = 0.1" translated to this workload).
        let best = vres
            .metrics
            .min_y("loss_vs_iter")
            .unwrap()
            .min(mres.metrics.min_y("loss_vs_iter").unwrap());
        let target = best * 1.05;
        PointResult {
            name: name.to_string(),
            base_degree: g.max_degree(),
            cb,
            eff_max,
            van_time: vres.total_time,
            matcha_time: mres.total_time,
            van_ttt: vres.metrics.first_x_below("loss_vs_time", target),
            matcha_ttt: mres.metrics.first_x_below("loss_vs_time", target),
        }
    });

    let mut t = Table::new(&[
        "topology",
        "Δ(base)",
        "CB",
        "eff. max deg",
        "van time",
        "matcha time",
        "van t->tgt",
        "matcha t->tgt",
    ]);
    let mut prev_vanilla_time = 0.0;
    for r in &results {
        t.row(&[
            r.name.clone(),
            r.base_degree.to_string(),
            format!("{}", r.cb),
            format!("{:.2}", r.eff_max),
            format!("{:.0}", r.van_time),
            format!("{:.0}", r.matcha_time),
            r.van_ttt.map(|x| format!("{x:.0}")).unwrap_or("—".into()),
            r.matcha_ttt.map(|x| format!("{x:.0}")).unwrap_or("—".into()),
        ]);

        // §5 claim is *flatness*: the chosen budgets pin the effective
        // degree to a small, roughly constant value (the paper quotes ≈4
        // for its instances; exact values depend on the random graph and
        // the decomposition, so assert the band rather than the point).
        assert!(
            (1.8..=5.5).contains(&r.eff_max),
            "{}: effective max degree {:.2} outside the pinned band",
            r.name,
            r.eff_max
        );
        assert!(
            r.matcha_time < r.van_time,
            "{}: MATCHA total time must beat vanilla",
            r.name
        );
        if let (Some(v), Some(m)) = (r.van_ttt, r.matcha_ttt) {
            assert!(
                m <= v * 1.05,
                "{}: MATCHA time-to-target {m} vs vanilla {v}",
                r.name
            );
        }
        // Paper: vanilla's wall time grows with density, MATCHA's stays flat.
        if prev_vanilla_time > 0.0 {
            assert!(
                r.van_time >= prev_vanilla_time * 0.8,
                "vanilla time should not shrink with density"
            );
        }
        prev_vanilla_time = r.van_time;
    }
    t.print();
    println!(
        "\nreading: effective max degree pinned ≈4 for all three graphs; MATCHA's \
         total virtual time stays nearly flat while vanilla's grows with density. ✓"
    );
}
