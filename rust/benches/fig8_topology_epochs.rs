//! Figure 8: training loss vs epochs across the 16-node topologies —
//! with a properly chosen budget, MATCHA's per-epoch loss can be *lower*
//! than vanilla DecenSGD's (its optimized random topology has a smaller
//! spectral norm; see Fig 3b/3c). The ρ scan and both runs go through the
//! `experiment` plan/run pipeline (seeds pinned to the historical
//! values).

use matcha::benchkit::Table;
use matcha::experiment::{self, ExperimentResult, ExperimentSpec, Plan, ProblemSpec, Strategy};
use matcha::graph::{paper_figure9_topologies, Graph};

fn spec(g: &Graph, strategy: Strategy, iters: usize) -> ExperimentSpec {
    ExperimentSpec::on_graph(g.clone())
        .strategy(strategy)
        .problem(ProblemSpec::Logistic { non_iid: 0.8, separation: 1.5, seed: Some(123) })
        .lr(0.1)
        .iterations(iters)
        .record_every(50)
        .seed(6)
        .sampler_seed(51)
}

fn main() {
    let iters = 2500;
    let mut t = Table::new(&[
        "topology",
        "CB*",
        "rho vanilla",
        "rho matcha",
        "tail loss vanilla",
        "tail loss matcha",
    ]);

    for (name, g) in paper_figure9_topologies() {
        // Pick the budget whose optimized ρ is smallest (the paper's
        // "proper communication budget") by planning the whole scan.
        let mut best: Option<Plan> = None;
        let mut best_cb = 1.0;
        for i in 2..=10 {
            let cb = i as f64 / 10.0;
            let plan = Plan::for_graph(g.clone(), Strategy::Matcha { budget: cb }).unwrap();
            let improves = match &best {
                None => true,
                Some(b) => plan.rho < b.rho,
            };
            if improves {
                best_cb = cb;
                best = Some(plan);
            }
        }
        let mplan = best.unwrap();
        let vplan = Plan::for_graph(g.clone(), Strategy::Vanilla).unwrap();

        let vres = experiment::run(&spec(&g, Strategy::Vanilla, iters)).unwrap();
        let mres =
            experiment::run(&spec(&g, Strategy::Matcha { budget: best_cb }, iters)).unwrap();

        let tail = |r: &ExperimentResult| {
            let s = r.metrics.get("loss_vs_iter");
            let h = s.len() / 2;
            s[h..].iter().map(|x| x.y).sum::<f64>() / (s.len() - h) as f64
        };
        let (tv, tm) = (tail(&vres), tail(&mres));
        t.row(&[
            name.to_string(),
            format!("{best_cb}"),
            format!("{:.4}", vplan.rho),
            format!("{:.4}", mplan.rho),
            format!("{tv:.4}"),
            format!("{tm:.4}"),
        ]);
        // Core claim: at the ρ-optimal budget, per-epoch error is at
        // least on par with vanilla (lower ρ ⇒ lower error bound).
        assert!(
            tm <= tv * 1.05,
            "{name}: MATCHA tail loss {tm} should not exceed vanilla {tv}"
        );
        assert!(
            mplan.rho <= vplan.rho + 1e-9,
            "{name}: ρ-optimal budget should not be worse than vanilla"
        );
    }
    t.print();
    println!(
        "\nFig 8 claim holds: with a proper budget MATCHA's per-epoch loss \
         matches or beats vanilla on every topology. ✓"
    );
}
