//! Figure 8: training loss vs epochs across the 16-node topologies —
//! with a properly chosen budget, MATCHA's per-epoch loss can be *lower*
//! than vanilla DecenSGD's (its optimized random topology has a smaller
//! spectral norm; see Fig 3b/3c).

use matcha::benchkit::Table;
use matcha::budget::optimize_activation_probabilities;
use matcha::graph::paper_figure9_topologies;
use matcha::matching::decompose;
use matcha::mixing::{optimize_alpha, vanilla_design};
use matcha::sim::{run_decentralized, LogisticProblem, LogisticSpec, RunConfig};
use matcha::topology::{MatchaSampler, VanillaSampler};

fn main() {
    let iters = 2500;
    let mut t = Table::new(&[
        "topology",
        "CB*",
        "rho vanilla",
        "rho matcha",
        "tail loss vanilla",
        "tail loss matcha",
    ]);

    for (name, g) in paper_figure9_topologies() {
        let d = decompose(&g);
        // Pick the budget whose optimized ρ is smallest (the paper's
        // "proper communication budget").
        let (mut best_cb, mut best) = (1.0, f64::INFINITY);
        let mut best_probs = None;
        for i in 2..=10 {
            let cb = i as f64 / 10.0;
            let probs = optimize_activation_probabilities(&d, cb);
            let mix = optimize_alpha(&d, &probs.probabilities);
            if mix.rho < best {
                best = mix.rho;
                best_cb = cb;
                best_probs = Some((probs, mix));
            }
        }
        let (probs, mix) = best_probs.unwrap();
        let van = vanilla_design(&g.laplacian());

        let problem = LogisticProblem::generate(LogisticSpec {
            num_workers: g.num_nodes(),
            non_iid: 0.8,
            seed: 123,
            ..LogisticSpec::default()
        });
        let cfg = |alpha: f64| RunConfig {
            lr: 0.1,
            iterations: iters,
            record_every: 50,
            alpha,
            seed: 6,
            ..RunConfig::default()
        };
        let mut vs = VanillaSampler::new(d.len());
        let vres = run_decentralized(&problem, &d.matchings, &mut vs, &cfg(van.alpha));
        let mut ms = MatchaSampler::new(probs.probabilities.clone(), 51);
        let mres = run_decentralized(&problem, &d.matchings, &mut ms, &cfg(mix.alpha));

        let tail = |r: &matcha::sim::RunResult| {
            let s = r.metrics.get("loss_vs_iter");
            let h = s.len() / 2;
            s[h..].iter().map(|x| x.y).sum::<f64>() / (s.len() - h) as f64
        };
        let (tv, tm) = (tail(&vres), tail(&mres));
        t.row(&[
            name.to_string(),
            format!("{best_cb}"),
            format!("{:.4}", van.rho),
            format!("{:.4}", mix.rho),
            format!("{tv:.4}"),
            format!("{tm:.4}"),
        ]);
        // Core claim: at the ρ-optimal budget, per-epoch error is at
        // least on par with vanilla (lower ρ ⇒ lower error bound).
        assert!(
            tm <= tv * 1.05,
            "{name}: MATCHA tail loss {tm} should not exceed vanilla {tv}"
        );
        assert!(
            mix.rho <= van.rho + 1e-9,
            "{name}: ρ-optimal budget should not be worse than vanilla"
        );
    }
    t.print();
    println!(
        "\nFig 8 claim holds: with a proper budget MATCHA's per-epoch loss \
         matches or beats vanilla on every topology. ✓"
    );
}
