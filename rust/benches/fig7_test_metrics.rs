//! Figures 7 & 10: held-out (test) accuracy of MATCHA at several budgets
//! vs vanilla DecenSGD — generalization is preserved, not just training
//! loss. Fig 10's across-topology version is covered by the second block.
//! Runs are spec-driven (`experiment::run`) with the historical problem
//! and sampler seeds pinned.

use matcha::benchkit::Table;
use matcha::experiment::{self, ExperimentSpec, ProblemSpec, Strategy};
use matcha::graph::{paper_figure1_graph, paper_figure9_topologies, Graph};

fn accuracy_run(g: &Graph, cb: Option<f64>, iters: usize, seed: u64) -> f64 {
    let strategy = match cb {
        None => Strategy::Vanilla,
        Some(cb) => Strategy::Matcha { budget: cb },
    };
    let spec = ExperimentSpec::on_graph(g.clone())
        .strategy(strategy)
        .problem(ProblemSpec::Logistic { non_iid: 0.5, separation: 1.5, seed: Some(900 + seed) })
        .lr(0.1)
        .iterations(iters)
        .record_every(50)
        .seed(seed)
        .sampler_seed(seed ^ 0xfeed);
    let res = experiment::run(&spec).expect("accuracy run");
    res.metrics.last("test_acc_vs_iter").unwrap()
}

fn main() {
    let iters = 2000;

    // --- Fig 7: budgets on the 8-node graph ----------------------------
    let g = paper_figure1_graph();
    println!("=== Fig 7: test accuracy, fig1 graph ===");
    let mut t = Table::new(&["run", "final test acc"]);
    let van_acc = accuracy_run(&g, None, iters, 2);
    t.row(&["vanilla".into(), format!("{van_acc:.4}")]);
    let mut accs = vec![];
    for cb in [0.5, 0.1, 0.02] {
        let acc = accuracy_run(&g, Some(cb), iters, 2);
        t.row(&[format!("matcha CB={cb}"), format!("{acc:.4}")]);
        accs.push(acc);
    }
    t.print();
    for (cb, acc) in [0.5, 0.1, 0.02].iter().zip(&accs) {
        assert!(
            acc >= &(van_acc - 0.03),
            "CB={cb}: test accuracy {acc} fell behind vanilla {van_acc}"
        );
    }
    println!("test accuracy preserved at all budgets. ✓");

    // --- Fig 10: across 16-node topologies ------------------------------
    println!("\n=== Fig 10: test accuracy across topologies (CB per Fig 5) ===");
    let mut t2 = Table::new(&["topology", "vanilla acc", "matcha acc"]);
    for ((name, g16), cb) in paper_figure9_topologies().iter().zip([0.75, 0.4, 0.3]) {
        let va = accuracy_run(g16, None, iters, 3);
        let ma = accuracy_run(g16, Some(cb), iters, 3);
        t2.row(&[name.to_string(), format!("{va:.4}"), format!("{ma:.4}")]);
        assert!(ma >= va - 0.03, "{name}: MATCHA acc {ma} vs vanilla {va}");
    }
    t2.print();
    println!("Fig 10 claim holds: accuracy matched or exceeded at reduced budgets. ✓");
}
