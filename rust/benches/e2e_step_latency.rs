//! End-to-end step latency of the XLA path: the AOT train step (fused
//! and Pallas variants) and the gossip mix step, measured through the
//! same runtime the coordinator uses. Requires `make artifacts`.
//!
//! This is the per-iteration computation-time measurement that calibrates
//! `compute_units` in the delay model (DESIGN.md §Hardware-Adaptation).

use matcha::benchkit::bench;
use matcha::config::{ArtifactPaths, ModelMeta};
use matcha::data::{BatchIter, Corpus};
use matcha::rng::Rng;
use matcha::runtime::{literal_f32, literal_i32, literal_scalar_f32, Runtime};

fn main() -> anyhow::Result<()> {
    let artifacts = ArtifactPaths::new("artifacts");
    if !artifacts.meta().exists() {
        println!("skipping: no artifacts (run `make artifacts`)");
        return Ok(());
    }
    let meta = ModelMeta::load(&artifacts.meta()).map_err(anyhow::Error::msg)?;
    println!(
        "model: preset={} params={} batch={} seq={} workers={}",
        meta.preset, meta.param_count, meta.batch, meta.seq_len, meta.workers
    );

    let rt = Runtime::cpu()?;
    let fused = rt.load_hlo(&artifacts.train_step(false))?;
    let pallas = rt.load_hlo(&artifacts.train_step(true))?;
    let mix = rt.load_hlo(&artifacts.mix(false))?;
    let mix_pallas = rt.load_hlo(&artifacts.mix(true))?;
    let eval = rt.load_hlo(&artifacts.eval_step())?;

    let mut rng = Rng::new(7);
    let flat = meta.init_params(&mut rng);
    let corpus = Corpus::synthesize(1, 10_000, 1000, false, 3);
    let mut it = BatchIter::new(&corpus.shards[0].tokens, meta.batch, meta.seq_len, 1);
    let (xs, ys) = it.next_batch();
    let dims = [meta.batch as i64, meta.seq_len as i64];
    let d = meta.param_count;

    let inputs = || -> anyhow::Result<Vec<xla::Literal>> {
        Ok(vec![
            literal_f32(&flat, &[d as i64])?,
            literal_i32(&xs, &dims)?,
            literal_i32(&ys, &dims)?,
            literal_scalar_f32(0.1),
        ])
    };

    let ins = inputs()?;
    bench("train_step fused (xla dot)", 12, 2, || {
        fused.run(&ins).unwrap();
    });
    let ins_p = inputs()?;
    bench("train_step pallas (interpret)", 5, 1, || {
        pallas.run(&ins_p).unwrap();
    });
    let ev = vec![
        literal_f32(&flat, &[d as i64])?,
        literal_i32(&xs, &dims)?,
        literal_i32(&ys, &dims)?,
    ];
    bench("eval_step", 12, 2, || {
        eval.run(&ev).unwrap();
    });

    // Mix: m workers' stacked parameters, ring W.
    let m = meta.workers;
    let mut w = vec![0.0f32; m * m];
    for i in 0..m {
        w[i * m + i] = 1.0 - 2.0 * 0.3;
        w[i * m + (i + 1) % m] = 0.3;
        w[i * m + (i + m - 1) % m] = 0.3;
    }
    let mut stacked = Vec::with_capacity(m * d);
    for k in 0..m {
        stacked.extend(flat.iter().map(|v| v + k as f32 * 1e-3));
    }
    let mix_ins = vec![
        literal_f32(&w, &[m as i64, m as i64])?,
        literal_f32(&stacked, &[m as i64, d as i64])?,
    ];
    bench("mix step fused (m x d gossip)", 20, 3, || {
        mix.run(&mix_ins).unwrap();
    });
    bench("mix step pallas (interpret)", 5, 1, || {
        mix_pallas.run(&mix_ins).unwrap();
    });
    Ok(())
}
