//! Figure 3 (a,b,c): spectral norm ρ vs communication budget, MATCHA vs
//! P-DecenSGD, on the paper's three analysis topologies. The whole curve
//! is planning-only — `experiment::Plan` per (strategy, budget) point.
//!
//! Shape claims to reproduce:
//!   1. MATCHA's ρ at CB ≈ 0.5 matches vanilla's (≈ same error/epoch at
//!      half the communication);
//!   2. for a fixed ρ, MATCHA needs much less budget than P-DecenSGD;
//!   3. on the denser 16-node graphs there is a CB < 1 where MATCHA's ρ
//!      *beats* vanilla.

use matcha::benchkit::{bench_auto, Table};
use matcha::experiment::{Plan, Strategy};
use matcha::graph::{
    find_er_with_max_degree, find_geometric_with_max_degree, paper_figure1_graph, Graph,
};

fn run_curve(label: &str, g: &Graph) -> (f64, f64, f64) {
    let van = Plan::for_graph(g.clone(), Strategy::Vanilla).unwrap();
    println!(
        "\n=== {label}: m={} Δ={} M={} | vanilla ρ = {:.4} ===",
        g.num_nodes(),
        g.max_degree(),
        van.decomposition.len(),
        van.rho
    );
    let mut t = Table::new(&["CB", "rho MATCHA", "rho P-DecenSGD", "lambda2"]);
    let mut best_rho = f64::INFINITY;
    let mut rho_at_half = f64::NAN;
    for i in 1..=10 {
        let cb = i as f64 / 10.0;
        let matcha = Plan::for_graph(g.clone(), Strategy::Matcha { budget: cb }).unwrap();
        let periodic = Plan::for_graph(g.clone(), Strategy::Periodic { budget: cb }).unwrap();
        t.row(&[
            format!("{cb:.1}"),
            format!("{:.4}", matcha.rho),
            format!("{:.4}", periodic.rho),
            format!("{:.4}", matcha.lambda2),
        ]);
        best_rho = best_rho.min(matcha.rho);
        if (cb - 0.5).abs() < 1e-9 {
            rho_at_half = matcha.rho;
        }
        // Claim 2: MATCHA dominates P-DecenSGD point-wise in budget.
        assert!(
            matcha.rho <= periodic.rho + 1e-6,
            "{label} CB={cb}: MATCHA ρ {} worse than periodic {}",
            matcha.rho,
            periodic.rho
        );
    }
    t.print();
    (van.rho, rho_at_half, best_rho)
}

fn main() {
    let fig3a = paper_figure1_graph();
    let fig3b = find_geometric_with_max_degree(16, 10, 202);
    let fig3c = find_er_with_max_degree(16, 8, 303);

    let (van_a, half_a, _) = run_curve("Fig 3a: 8-node (Δ=5)", &fig3a);
    let (van_b, _, best_b) = run_curve("Fig 3b: 16-node geometric (Δ=10)", &fig3b);
    let (van_c, _, best_c) = run_curve("Fig 3c: 16-node Erdős–Rényi (Δ=8)", &fig3c);

    // Claim 1 (Fig 3a): ρ at CB=0.5 close to vanilla's.
    println!("\nFig3a: vanilla ρ {:.4}, MATCHA@0.5 ρ {:.4}", van_a, half_a);
    assert!(
        half_a <= van_a + 0.08,
        "CB=0.5 should roughly preserve vanilla's spectral norm"
    );
    // Claim 3 (Fig 3b/3c): some budget beats vanilla on the dense graphs.
    assert!(
        best_b < van_b + 1e-9 || best_c < van_c + 1e-9,
        "denser graphs: expected some CB with ρ below vanilla (3b: {best_b} vs {van_b}, 3c: {best_c} vs {van_c})"
    );
    println!("claims 1–3 hold. ✓");

    println!("\n=== hot-path timings ===");
    bench_auto("plan(16-node, matcha cb=0.5)", 400, || {
        std::hint::black_box(
            Plan::for_graph(fig3b.clone(), Strategy::Matcha { budget: 0.5 }).unwrap(),
        );
    });
}
