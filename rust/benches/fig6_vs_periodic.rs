//! Figure 6: MATCHA vs P-DecenSGD vs vanilla at matched budgets —
//! per-epoch error. Paper claim: MATCHA's error-vs-epoch curve is nearly
//! identical to vanilla's, while P-DecenSGD at the same budget is
//! consistently worse.
//!
//! Workload: a strongly heterogeneous noisy quadratic, where the
//! suboptimality plateau scales with the higher-order ρ terms of
//! Theorem 1 — exactly the regime where the consensus quality separates
//! the strategies. All three runs are one spec with the strategy swapped
//! (problem and sampler seeds pinned to the historical values).

use matcha::benchkit::Table;
use matcha::experiment::{self, ExperimentSpec, ProblemSpec, Strategy};

fn spec(strategy: Strategy) -> ExperimentSpec {
    ExperimentSpec::new("fig1")
        .strategy(strategy)
        .problem(ProblemSpec::Quadratic { dim: 24, hetero: 4.0, noise_std: 1.0, seed: Some(88) })
        .lr(0.04)
        .iterations(3000)
        .record_every(50)
        .seed(1)
        .sampler_seed(31)
}

fn main() {
    let cb = 0.4;

    let vplan = experiment::plan(&spec(Strategy::Vanilla)).unwrap();
    let mplan = experiment::plan(&spec(Strategy::Matcha { budget: cb })).unwrap();
    let pplan = experiment::plan(&spec(Strategy::Periodic { budget: cb })).unwrap();
    println!(
        "spectral norms: vanilla {:.4} | matcha@{cb} {:.4} | periodic@{cb} {:.4}",
        vplan.rho, mplan.rho, pplan.rho
    );

    let vres = experiment::run(&spec(Strategy::Vanilla)).unwrap();
    let mres = experiment::run(&spec(Strategy::Matcha { budget: cb })).unwrap();
    let pres = experiment::run(&spec(Strategy::Periodic { budget: cb })).unwrap();

    println!("\n=== Fig 6: suboptimality F(x̄) − F* vs iteration at CB = {cb} ===");
    let mut t = Table::new(&["iter", "vanilla", "MATCHA", "P-DecenSGD"]);
    let (v, m, p) = (
        vres.metrics.get("subopt_vs_iter"),
        mres.metrics.get("subopt_vs_iter"),
        pres.metrics.get("subopt_vs_iter"),
    );
    for i in (0..v.len()).step_by(5) {
        t.row(&[
            format!("{}", v[i].x),
            format!("{:.5}", v[i].y),
            format!("{:.5}", m[i].y),
            format!("{:.5}", p[i].y),
        ]);
    }
    t.print();

    // Mean suboptimality over the back half (the plateau Theorem 1 bounds).
    let half = v.len() / 2;
    let mean = |s: &[matcha::metrics::Sample]| -> f64 {
        s[half..].iter().map(|x| x.y).sum::<f64>() / (s.len() - half) as f64
    };
    let (mv, mm, mp) = (mean(v), mean(m), mean(p));
    println!("\nmean tail suboptimality: vanilla {mv:.5}, MATCHA {mm:.5}, P-DecenSGD {mp:.5}");

    // Consensus distance — the discrepancy term of the Theorem-1 proof.
    let cm = mres.metrics.last("consensus_vs_iter").unwrap();
    let cp = pres.metrics.last("consensus_vs_iter").unwrap();
    let cv = vres.metrics.last("consensus_vs_iter").unwrap();
    println!("final consensus distance: vanilla {cv:.3e}, MATCHA {cm:.3e}, P-DecenSGD {cp:.3e}");

    // Claims: MATCHA ≈ vanilla per-iteration; P-DecenSGD worse than both
    // in consensus and no better in suboptimality.
    assert!(
        mm <= mv * 1.35,
        "MATCHA tail suboptimality {mm} should track vanilla {mv}"
    );
    assert!(
        mp >= mm * 0.95,
        "P-DecenSGD {mp} should be no better than MATCHA {mm}"
    );
    assert!(
        cp > cm,
        "P-DecenSGD consensus distance {cp} should exceed MATCHA's {cm}"
    );
    println!("Fig 6 shape claims hold. ✓");
}
