//! Figure 6: MATCHA vs P-DecenSGD vs vanilla at matched budgets —
//! per-epoch error. Paper claim: MATCHA's error-vs-epoch curve is nearly
//! identical to vanilla's, while P-DecenSGD at the same budget is
//! consistently worse.
//!
//! Workload: a strongly heterogeneous noisy quadratic, where the
//! suboptimality plateau scales with the higher-order ρ terms of
//! Theorem 1 — exactly the regime where the consensus quality separates
//! the strategies (the paper's deep-learning version of this figure sees
//! the separation through the same mechanism).

use matcha::benchkit::Table;
use matcha::budget::optimize_activation_probabilities;
use matcha::graph::paper_figure1_graph;
use matcha::matching::decompose;
use matcha::mixing::{optimize_alpha, optimize_alpha_periodic, vanilla_design};
use matcha::rng::Rng;
use matcha::sim::{run_decentralized, QuadraticProblem, RunConfig};
use matcha::topology::{MatchaSampler, PeriodicSampler, VanillaSampler};

fn main() {
    let g = paper_figure1_graph();
    let d = decompose(&g);
    let cb = 0.4;
    let iters = 3000;

    // Strong heterogeneity + gradient noise: consensus quality matters.
    let problem = {
        let mut r = Rng::new(88);
        QuadraticProblem::generate(g.num_nodes(), 24, 4.0, 1.0, &mut r)
    };
    let cfg = |alpha: f64| RunConfig {
        lr: 0.04,
        iterations: iters,
        record_every: 50,
        alpha,
        seed: 1,
        ..RunConfig::default()
    };

    let van = vanilla_design(&g.laplacian());
    let probs = optimize_activation_probabilities(&d, cb);
    let matcha = optimize_alpha(&d, &probs.probabilities);
    let periodic = optimize_alpha_periodic(&g.laplacian(), cb);
    println!(
        "spectral norms: vanilla {:.4} | matcha@{cb} {:.4} | periodic@{cb} {:.4}",
        van.rho, matcha.rho, periodic.rho
    );

    let mut vs = VanillaSampler::new(d.len());
    let vres = run_decentralized(&problem, &d.matchings, &mut vs, &cfg(van.alpha));
    let mut ms = MatchaSampler::new(probs.probabilities.clone(), 31);
    let mres = run_decentralized(&problem, &d.matchings, &mut ms, &cfg(matcha.alpha));
    let mut ps = PeriodicSampler::from_budget(d.len(), cb);
    let pres = run_decentralized(&problem, &d.matchings, &mut ps, &cfg(periodic.alpha));

    println!("\n=== Fig 6: suboptimality F(x̄) − F* vs iteration at CB = {cb} ===");
    let mut t = Table::new(&["iter", "vanilla", "MATCHA", "P-DecenSGD"]);
    let (v, m, p) = (
        vres.metrics.get("subopt_vs_iter"),
        mres.metrics.get("subopt_vs_iter"),
        pres.metrics.get("subopt_vs_iter"),
    );
    for i in (0..v.len()).step_by(5) {
        t.row(&[
            format!("{}", v[i].x),
            format!("{:.5}", v[i].y),
            format!("{:.5}", m[i].y),
            format!("{:.5}", p[i].y),
        ]);
    }
    t.print();

    // Mean suboptimality over the back half (the plateau Theorem 1 bounds).
    let half = v.len() / 2;
    let mean = |s: &[matcha::metrics::Sample]| -> f64 {
        s[half..].iter().map(|x| x.y).sum::<f64>() / (s.len() - half) as f64
    };
    let (mv, mm, mp) = (mean(v), mean(m), mean(p));
    println!("\nmean tail suboptimality: vanilla {mv:.5}, MATCHA {mm:.5}, P-DecenSGD {mp:.5}");

    // Consensus distance — the discrepancy term of the Theorem-1 proof.
    let cm = mres.metrics.last("consensus_vs_iter").unwrap();
    let cp = pres.metrics.last("consensus_vs_iter").unwrap();
    let cv = vres.metrics.last("consensus_vs_iter").unwrap();
    println!("final consensus distance: vanilla {cv:.3e}, MATCHA {cm:.3e}, P-DecenSGD {cp:.3e}");

    // Claims: MATCHA ≈ vanilla per-iteration; P-DecenSGD worse than both
    // in consensus and no better in suboptimality.
    assert!(
        mm <= mv * 1.35,
        "MATCHA tail suboptimality {mm} should track vanilla {mv}"
    );
    assert!(
        mp >= mm * 0.95,
        "P-DecenSGD {mp} should be no better than MATCHA {mm}"
    );
    assert!(
        cp > cm,
        "P-DecenSGD consensus distance {cp} should exceed MATCHA's {cm}"
    );
    println!("Fig 6 shape claims hold. ✓");
}
