//! Ablations of MATCHA's design choices (DESIGN.md §4):
//!
//! 1. **Decomposition quality** — Misra–Gries (M ≤ Δ+1) vs greedy
//!    (M ≤ 2Δ−1). More matchings = more sequential rounds for vanilla
//!    DecenSGD, and a worse ρ-per-budget curve for MATCHA.
//! 2. **Optimized vs uniform activation** — problem (4)'s solution vs
//!    splitting the budget evenly across matchings.
//! 3. **Independent Bernoulli vs single-matching sampling** (§3's
//!    extension): same expected budget, different activation law.

use matcha::benchkit::Table;
use matcha::budget::{expected_laplacian, optimize_activation_probabilities, periodic_probabilities};
use matcha::graph::{self, Graph};
use matcha::matching::{decompose, decompose_greedy};
use matcha::mixing::{optimize_alpha, optimize_alpha_from_laplacians, variance_laplacian};
use matcha::rng::Rng;

fn zoo() -> Vec<(String, Graph)> {
    let mut rng = Rng::new(17);
    vec![
        ("fig1".into(), graph::paper_figure1_graph()),
        ("complete8".into(), graph::complete(8)),
        ("geom16d10".into(), graph::find_geometric_with_max_degree(16, 10, 202)),
        ("er16".into(), graph::erdos_renyi_connected(16, 0.5, &mut rng)),
    ]
}

fn main() {
    // --- 1. coloring quality ------------------------------------------
    println!("=== ablation 1: Misra–Gries vs greedy edge coloring ===");
    let mut t = Table::new(&["graph", "Δ", "M (MG)", "M (greedy)", "rho@0.4 MG", "rho@0.4 greedy"]);
    for (name, g) in zoo() {
        let mg = decompose(&g);
        let gr = decompose_greedy(&g);
        let pm = optimize_activation_probabilities(&mg, 0.4);
        let am = optimize_alpha(&mg, &pm.probabilities);
        let pg = optimize_activation_probabilities(&gr, 0.4);
        let ag = optimize_alpha(&gr, &pg.probabilities);
        t.row(&[
            name.clone(),
            g.max_degree().to_string(),
            mg.len().to_string(),
            gr.len().to_string(),
            format!("{:.4}", am.rho),
            format!("{:.4}", ag.rho),
        ]);
        // Guarantees: MG within Vizing's bound, greedy within 2Δ−1.
        // (Greedy can tie or even win on small graphs; MG's value is the
        // worst-case guarantee, which greedy lacks.)
        assert!(mg.len() <= g.max_degree() + 1, "{name}: MG broke Vizing");
        assert!(gr.len() <= (2 * g.max_degree()).saturating_sub(1).max(1), "{name}: greedy bound");
    }
    t.print();
    println!("(fewer matchings ⇒ fewer sequential rounds at full budget; only MG guarantees Δ+1)");

    // --- 2. optimized vs uniform probabilities --------------------------
    println!("\n=== ablation 2: optimized (problem 4) vs uniform activation ===");
    let mut t2 = Table::new(&["graph", "CB", "λ₂ optimized", "λ₂ uniform", "rho opt", "rho unif"]);
    for (name, g) in zoo() {
        let d = decompose(&g);
        for cb in [0.2, 0.5] {
            let opt = optimize_activation_probabilities(&d, cb);
            let uni = periodic_probabilities(&d, cb);
            let ao = optimize_alpha(&d, &opt.probabilities);
            let au = optimize_alpha(&d, &uni.probabilities);
            t2.row(&[
                name.clone(),
                format!("{cb}"),
                format!("{:.4}", opt.lambda2),
                format!("{:.4}", uni.lambda2),
                format!("{:.4}", ao.rho),
                format!("{:.4}", au.rho),
            ]);
            assert!(
                opt.lambda2 >= uni.lambda2 - 1e-7,
                "{name} cb={cb}: optimizer below uniform"
            );
        }
    }
    t2.print();
    println!("(the gap is the value of problem (4); it widens on irregular graphs)");

    // --- 3. Bernoulli vs single-matching activation law ------------------
    // Same expected budget Σp = 1: independent activation vs exactly one
    // matching per round drawn ∝ p. For the single-matching law
    // E[LᵀL] = Σ q_j L_jᵀL_j = 2 Σ q_j L_j (matching Laplacians are
    // idempotent-like: L² = 2L), so ρ comes from L̄ = Σq_jL_j and
    // E[WᵀW] = I − 2αL̄ + 2α²L̄ → reuse the library path with
    // L̃' = L̄ − "coupling"; here we evaluate it directly.
    println!("\n=== ablation 3: independent Bernoulli vs single-matching sampling ===");
    let mut t3 = Table::new(&["graph", "rho bernoulli(Σp=1)", "rho single-matching"]);
    for (name, g) in zoo() {
        let d = decompose(&g);
        let m = d.len() as f64;
        let laps = d.laplacians();
        // Budget CB·M = 1 ⇒ cb = 1/M.
        let probs = optimize_activation_probabilities(&d, 1.0 / m);
        let bern = optimize_alpha(&d, &probs.probabilities);
        // Single-matching with q ∝ optimized p (Σq = 1):
        let total: f64 = probs.probabilities.iter().sum();
        let q: Vec<f64> = probs.probabilities.iter().map(|p| p / total).collect();
        // E[WᵀW] − J = I − 2αL̄q + α²·E[L²] − J with E[L²] = Σ qⱼ Lⱼ² = 2L̄q.
        let lbar = expected_laplacian(&laps, &q);
        // Reuse optimize_alpha_from_laplacians: it expects E[L²] = L̄² + 2L̃;
        // single-matching has E[L²] = 2L̄, so pass L̃ = (2L̄ − L̄²)/2.
        let mut ltilde = lbar.clone();
        let lbar2 = lbar.matmul(&lbar);
        ltilde.axpy(-0.5, &lbar2);
        let single = optimize_alpha_from_laplacians(&lbar, &ltilde);
        t3.row(&[
            name.clone(),
            format!("{:.4}", bern.rho),
            format!("{:.4}", single.rho),
        ]);
        assert!(bern.rho < 1.0 && single.rho < 1.0);
    }
    t3.print();
    println!("(both laws converge; the library defaults to independent Bernoulli as in the paper)");

    // --- 4. compression combination (§1: "easily combined") -------------
    println!("\n=== ablation 4: MATCHA × gossip compression (CB=0.5, latency floor 0.05) ===");
    {
        use matcha::experiment::{self, ExperimentSpec, ProblemSpec, Strategy};
        use matcha::sim::Compression;
        let base = || {
            ExperimentSpec::new("fig1")
                .strategy(Strategy::Matcha { budget: 0.5 })
                .problem(ProblemSpec::Quadratic {
                    dim: 16,
                    hetero: 1.0,
                    noise_std: 0.3,
                    seed: Some(404),
                })
                .lr(0.02)
                .iterations(1200)
                .record_every(200)
                .seed(2)
                .sampler_seed(12)
        };
        let mut t4 = Table::new(&["scheme", "comm units", "final subopt"]);
        for (label, comp) in [
            ("matcha".to_string(), None),
            ("matcha + top-25%".to_string(), Some(Compression::TopK { frac: 0.25 })),
            ("matcha + 8-bit quant".to_string(), Some(Compression::Quantize { bits: 8 })),
        ] {
            let spec = match comp {
                None => base(),
                Some(c) => base().compression(c),
            };
            let res = experiment::run(&spec).expect("ablation 4 run");
            t4.row(&[
                label,
                format!("{:.0}", res.total_comm_units),
                format!("{:.4}", res.metrics.last("subopt_vs_iter").unwrap()),
            ]);
        }
        t4.print();
        println!("(compression multiplies MATCHA's savings in bandwidth-bound regimes)");
    }

    // Sanity cross-check of the L̃ algebra above on one case: Monte-Carlo.
    let g = graph::paper_figure1_graph();
    let d = decompose(&g);
    let laps = d.laplacians();
    let probs = vec![0.3; d.len()];
    let lbar = expected_laplacian(&laps, &probs);
    let ltilde = variance_laplacian(&laps, &probs);
    let design = optimize_alpha_from_laplacians(&lbar, &ltilde);
    let mut rng = Rng::new(1);
    let mc = matcha::mixing::rho_monte_carlo(&d, &probs, design.alpha, 8000, &mut rng);
    assert!((mc - design.rho).abs() < 0.03, "MC {mc} vs closed-form {}", design.rho);
    println!("\nMonte-Carlo cross-check passed ({mc:.4} vs {:.4}). ✓", design.rho);
}
