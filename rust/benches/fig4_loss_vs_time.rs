//! Figure 4 (+ §1 headline claims): training loss vs epochs and vs
//! wall-clock for MATCHA at CB ∈ {2%, 10%, 50%} against vanilla
//! DecenSGD, on the Figure-1 topology.
//!
//! Substrate: the fast simulator on a non-IID logistic-regression task in
//! a communication-dominated regime (compute ≪ comm, like WRN/CIFAR-100
//! over Ethernet), driven through the `experiment` spec API (problem and
//! sampler seeds pinned to the historical values, so the trajectories are
//! unchanged). Shape claims to reproduce:
//!   (d–f) at CB = 0.5 the loss-vs-epoch curve is nearly identical to
//!         vanilla;
//!   (a–c) in wall-clock, low budgets reach a loss target several times
//!         faster; per-iteration communication shrinks ~50x at CB = 0.02.

use matcha::benchkit::Table;
use matcha::experiment::{
    self, ExperimentResult, ExperimentSpec, NoopObserver, ProblemSpec, Strategy,
};

fn spec(strategy: Strategy) -> ExperimentSpec {
    ExperimentSpec::new("fig1")
        .strategy(strategy)
        .problem(ProblemSpec::Logistic { non_iid: 0.8, separation: 2.0, seed: Some(5) })
        .lr(0.1)
        .iterations(3000)
        .record_every(30)
        // Communication-dominated regime: computing one minibatch costs
        // 0.2 link-units (the CIFAR-100/WRN plots are in this regime).
        .compute_units(0.2)
        .seed(1)
        .sampler_seed(21)
}

fn main() {
    let iters = 3000;
    let mut results: Vec<(String, f64, ExperimentResult)> = Vec::new();
    results.push((
        "vanilla".into(),
        1.0,
        experiment::run(&spec(Strategy::Vanilla)).expect("vanilla run"),
    ));
    for cb in [0.5, 0.1, 0.02] {
        let s = spec(Strategy::Matcha { budget: cb });
        let plan = experiment::plan(&s).expect("plan");
        let label = format!("matcha CB={cb}");
        println!(
            "{label}: Σp = {:.3}, α = {:.4}, ρ = {:.4}, E[comm] = {:.3}/iter",
            plan.probabilities.iter().sum::<f64>(),
            plan.alpha,
            plan.rho,
            plan.expected_comm_units()
        );
        let run = experiment::run_planned(&s, &plan, &mut NoopObserver).expect("matcha run");
        results.push((label, cb, run));
    }

    // --- Fig 4 d–f analog: loss vs iterations --------------------------
    println!("\n=== Fig 4(d-f): loss vs iteration ===");
    let mut t = Table::new(&["iter", "vanilla", "CB=0.5", "CB=0.1", "CB=0.02"]);
    let series: Vec<&[matcha::metrics::Sample]> = results
        .iter()
        .map(|(_, _, r)| r.metrics.get("loss_vs_iter"))
        .collect();
    for idx in (0..series[0].len()).step_by(10) {
        t.row(&[
            format!("{}", series[0][idx].x),
            format!("{:.4}", series[0][idx].y),
            format!("{:.4}", series[1][idx].y),
            format!("{:.4}", series[2][idx].y),
            format!("{:.4}", series[3][idx].y),
        ]);
    }
    t.print();

    // --- Fig 4 a–c analog: time to reach a loss target ------------------
    let target = {
        // A loss every run eventually reaches: 10% above the best final.
        let best = results
            .iter()
            .map(|(_, _, r)| r.final_loss())
            .fold(f64::INFINITY, f64::min);
        best * 1.10
    };
    println!("\n=== Fig 4(a-c): virtual time to reach loss {target:.4} ===");
    let mut t2 = Table::new(&["run", "E[comm]/iter", "total time", "time-to-target", "speedup"]);
    let vanilla_ttt = results[0].2.metrics.first_x_below("loss_vs_time", target);
    for (name, _cb, r) in &results {
        let ttt = r.metrics.first_x_below("loss_vs_time", target);
        let speedup = match (vanilla_ttt, ttt) {
            (Some(v), Some(t)) => format!("{:.1}x", v / t),
            _ => "—".into(),
        };
        t2.row(&[
            name.clone(),
            format!("{:.3}", r.total_comm_units / iters as f64),
            format!("{:.0}", r.total_time),
            ttt.map(|t| format!("{t:.0}")).unwrap_or("—".into()),
            speedup,
        ]);
    }
    t2.print();

    // --- §1 headline claims ---------------------------------------------
    let comm_vanilla = results[0].2.total_comm_units;
    let comm_002 = results[3].2.total_comm_units;
    let comm_reduction = comm_vanilla / comm_002.max(1e-9);
    println!("\ncomm-delay reduction at CB=0.02: {comm_reduction:.0}x (paper: ~50x)");
    assert!(
        comm_reduction > 30.0,
        "expected ≳50x communication reduction, got {comm_reduction:.1}x"
    );

    // CB=0.5 per-epoch parity with vanilla (Fig 4d–f).
    let v_final = results[0].2.final_loss();
    let m_final = results[1].2.final_loss();
    assert!(
        (m_final - v_final).abs() < 0.05 * v_final.max(0.1),
        "CB=0.5 final loss {m_final} should track vanilla {v_final}"
    );
    // Wall-clock: low budgets strictly faster to target.
    if let (Some(v), Some(m)) = (
        vanilla_ttt,
        results[3].2.metrics.first_x_below("loss_vs_time", target),
    ) {
        assert!(m < v, "CB=0.02 should reach target sooner ({m} vs {v})");
        println!("time-to-target speedup at CB=0.02: {:.1}x (paper: up to 5x)", v / m);
    }
    println!("Fig 4 shape claims hold. ✓");
}
