//! Async vs barrier: wall-clock and virtual-time comparison of the
//! barrier-free gossip runtime (`backend: async`) against the barrier
//! engine's actor pool (`backend: actors`) under straggler and
//! flaky-link delay policies.
//!
//! Run: `cargo bench --bench async_vs_barrier` (append `-- --dry-run`
//! for the CI smoke variant: tiny runs, no assertions).
//!
//! BENCH NOTE (ISSUE 3 acceptance): on ≥ 4 cores, under the straggler
//! policy, async must demonstrate wall-clock ≤ barrier wall-clock and
//! strictly lower *virtual* time (the straggler gates every barrier
//! iteration; async overlaps its compute with communication). The
//! assertions below enforce both whenever the host has ≥ 4 hardware
//! threads. A `BENCH_async.json` summary (speedups, mean staleness) is
//! written either way to seed the perf trajectory.

use matcha::engine::available_threads;
use matcha::experiment::{self, Backend, ExperimentResult, ExperimentSpec, ProblemSpec, Strategy};
use matcha::json::Json;
use std::time::Instant;

struct Scenario {
    name: &'static str,
    policy: &'static str,
}

fn base_spec(policy: &str, iters: usize, backend: Backend) -> ExperimentSpec {
    ExperimentSpec::new("er:24:4:7")
        .strategy(Strategy::Matcha { budget: 0.5 })
        .problem(ProblemSpec::Quadratic { dim: 64, hetero: 1.0, noise_std: 0.2, seed: Some(7) })
        .policy(policy)
        .backend(backend)
        .lr(0.02)
        .iterations(iters)
        .record_every(iters.max(1))
        .seed(11)
        .sampler_seed(5)
}

/// Run the spec `repeats` times; return the (identical) result and the
/// fastest wall-clock in seconds.
fn timed(spec: &ExperimentSpec, repeats: usize) -> (ExperimentResult, f64) {
    let mut best = f64::INFINITY;
    let mut result = None;
    for _ in 0..repeats {
        let t0 = Instant::now();
        let r = experiment::run(spec).expect("bench run");
        best = best.min(t0.elapsed().as_secs_f64());
        result = Some(r);
    }
    (result.expect("at least one repeat"), best)
}

fn main() {
    let dry_run = std::env::args().any(|a| a == "--dry-run");
    let (iters, repeats) = if dry_run { (30, 1) } else { (600, 3) };
    let cores = available_threads();
    let threads = cores.clamp(2, 8);
    let max_staleness = 8;
    println!(
        "=== async vs barrier: 24 workers, {iters} iters, pool of {threads} threads \
         ({cores} hardware) ==="
    );

    let scenarios = [
        Scenario { name: "straggler", policy: "straggler:0:8.0" },
        Scenario { name: "flaky-links", policy: "flaky:0.15" },
    ];

    let mut table = matcha::benchkit::Table::new(&[
        "scenario",
        "mode",
        "virtual time",
        "wall (s)",
        "final loss",
        "mean staleness",
    ]);
    let mut summaries = Vec::new();
    let mut straggler_check = None;

    for sc in &scenarios {
        let barrier_spec = base_spec(sc.policy, iters, Backend::EngineActors { threads });
        let (barrier, barrier_wall) = timed(&barrier_spec, repeats);

        let async_spec =
            base_spec(sc.policy, iters, Backend::Async { threads, max_staleness });
        let (asy, async_wall) = timed(&async_spec, repeats);

        let stats = asy.async_stats.as_ref().expect("async stats");
        table.row(&[
            sc.name.to_string(),
            "barrier".to_string(),
            format!("{:.0}", barrier.total_time),
            format!("{barrier_wall:.3}"),
            format!("{:.5}", barrier.final_loss()),
            "-".to_string(),
        ]);
        table.row(&[
            sc.name.to_string(),
            "async".to_string(),
            format!("{:.0}", asy.total_time),
            format!("{async_wall:.3}"),
            format!("{:.5}", asy.final_loss()),
            format!("{:.3}", stats.mean_staleness()),
        ]);

        let virtual_speedup = barrier.total_time / asy.total_time.max(1e-12);
        let wall_speedup = barrier_wall / async_wall.max(1e-12);
        summaries.push(Json::obj(vec![
            ("scenario", Json::Str(sc.name.into())),
            ("virtual_time_barrier", Json::Num(barrier.total_time)),
            ("virtual_time_async", Json::Num(asy.total_time)),
            ("virtual_speedup", Json::Num(virtual_speedup)),
            ("wall_barrier_s", Json::Num(barrier_wall)),
            ("wall_async_s", Json::Num(async_wall)),
            ("wall_speedup", Json::Num(wall_speedup)),
            ("mean_staleness", Json::Num(stats.mean_staleness())),
            ("max_staleness", Json::Num(stats.max_staleness() as f64)),
            ("total_idle", Json::Num(stats.total_idle())),
            ("dropped_links", Json::Num(asy.dropped_links as f64)),
        ]));
        if sc.name == "straggler" {
            straggler_check = Some((
                barrier.total_time,
                asy.total_time,
                barrier_wall,
                async_wall,
                virtual_speedup,
                wall_speedup,
            ));
        }
    }
    table.print();

    let summary = Json::obj(vec![
        ("mode", Json::Str(if dry_run { "dry" } else { "full" }.into())),
        ("workers", Json::Num(24.0)),
        ("iterations", Json::Num(iters as f64)),
        ("threads", Json::Num(threads as f64)),
        ("max_staleness", Json::Num(max_staleness as f64)),
        ("scenarios", Json::Arr(summaries)),
    ]);
    std::fs::write("BENCH_async.json", summary.to_string()).expect("write BENCH_async.json");
    println!("\nwrote BENCH_async.json");

    let (vb, va, wb, wa, vs, ws) = straggler_check.expect("straggler scenario ran");
    println!(
        "straggler: virtual {va:.0} vs {vb:.0} ({vs:.2}x), wall {wa:.3}s vs {wb:.3}s ({ws:.2}x)"
    );
    if dry_run {
        println!("dry-run: skipping assertions");
        return;
    }
    assert!(
        va < vb,
        "BENCH NOTE violated: async virtual time {va} must beat barrier {vb} under a straggler"
    );
    if cores >= 4 {
        assert!(
            wa <= wb,
            "BENCH NOTE violated: async wall-clock {wa:.3}s exceeded barrier {wb:.3}s \
             on {cores} cores"
        );
        println!("bench note: async ≤ barrier wall-clock on ≥4 cores ✓");
    } else {
        println!("bench note: host has {cores} < 4 threads; wall-clock assertion skipped");
    }
}
