"""L2: the training model — a causal transformer language model over a
single flat f32 parameter vector.

Why flat parameters: the Rust coordinator (L3) treats each worker's state
as one `Vec<f32>` so the consensus step is a single (m, d) gossip matmul
(the `mix` Pallas kernel). This module defines the parameter layout
(`param_spec`), (un)flattening, the forward pass, the loss, and the three
functions that get AOT-lowered to HLO text by `aot.py`:

  * ``train_step(flat, x, y, lr) -> (new_flat, loss)`` — one local SGD
    step (paper eq. (2)'s inner bracket);
  * ``eval_step(flat, x, y) -> loss`` — held-out loss;
  * ``mix_step(w, stacked) -> stacked'`` — the consensus step W @ X.

Every dense projection routes through the Pallas tiled matmul
(`kernels/matmul.py`) when ``use_pallas=True``; with ``use_pallas=False``
the same graph uses `jnp.dot`, which XLA fuses aggressively — that
variant is also exported as the CPU fast path (see DESIGN.md
§Hardware-Adaptation: interpret-mode Pallas is a correctness vehicle on
this image, not a performance one).
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul as pallas_matmul
from .kernels.mix import mix as pallas_mix


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 64
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    seq_len: int = 64
    batch: int = 16
    use_pallas: bool = True

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(d_model=64, n_heads=2, n_layers=2, seq_len=32, batch=8),
    "small": ModelConfig(d_model=128, n_heads=4, n_layers=2, seq_len=64, batch=16),
    "medium": ModelConfig(d_model=256, n_heads=8, n_layers=4, seq_len=64, batch=16),
}


@dataclasses.dataclass(frozen=True)
class ParamEntry:
    name: str
    shape: Tuple[int, ...]
    offset: int
    init: str  # "normal" | "ones" | "zeros"
    std: float

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def param_spec(cfg: ModelConfig) -> List[ParamEntry]:
    """The flat-vector layout. Order is the contract with the Rust side
    (rust/src/config.rs parses the same list from artifacts/meta.json)."""
    entries: List[ParamEntry] = []
    offset = 0

    def add(name: str, shape: Tuple[int, ...], init: str, std: float = 0.0):
        nonlocal offset
        e = ParamEntry(name, shape, offset, init, std)
        entries.append(e)
        offset += e.size

    d = cfg.d_model
    add("embed", (cfg.vocab, d), "normal", d ** -0.5)
    add("pos", (cfg.seq_len, d), "normal", 0.01)
    for i in range(cfg.n_layers):
        add(f"layer{i}.ln1_scale", (d,), "ones")
        add(f"layer{i}.ln1_bias", (d,), "zeros")
        add(f"layer{i}.qkv", (d, 3 * d), "normal", d ** -0.5)
        add(f"layer{i}.attn_out", (d, d), "normal", (2.0 * d * cfg.n_layers) ** -0.5)
        add(f"layer{i}.ln2_scale", (d,), "ones")
        add(f"layer{i}.ln2_bias", (d,), "zeros")
        add(f"layer{i}.mlp_in", (d, cfg.d_ff), "normal", d ** -0.5)
        add(f"layer{i}.mlp_out", (cfg.d_ff, d), "normal", (2.0 * cfg.d_ff * cfg.n_layers) ** -0.5)
    add("ln_f_scale", (d,), "ones")
    add("ln_f_bias", (d,), "zeros")
    return entries


def param_count(cfg: ModelConfig) -> int:
    spec = param_spec(cfg)
    last = spec[-1]
    return last.offset + last.size


def init_params(cfg: ModelConfig, key) -> jnp.ndarray:
    """Reference initializer (the Rust side reimplements this from
    meta.json; python/tests cross-check statistics, not bit patterns)."""
    parts = []
    for e in param_spec(cfg):
        if e.init == "normal":
            key, sub = jax.random.split(key)
            parts.append(jax.random.normal(sub, e.shape, jnp.float32).reshape(-1) * e.std)
        elif e.init == "ones":
            parts.append(jnp.ones(e.size, jnp.float32))
        else:
            parts.append(jnp.zeros(e.size, jnp.float32))
    return jnp.concatenate(parts)


def unflatten(cfg: ModelConfig, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    out = {}
    for e in param_spec(cfg):
        out[e.name] = jax.lax.dynamic_slice(flat, (e.offset,), (e.size,)).reshape(e.shape)
    return out


def _mm(cfg: ModelConfig, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """2-D matmul through the Pallas kernel (or XLA dot)."""
    if cfg.use_pallas:
        return pallas_matmul(a, b)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def _layernorm(h: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    return (h - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _attention(cfg: ModelConfig, p: Dict[str, jnp.ndarray], i: int, h: jnp.ndarray) -> jnp.ndarray:
    b, t, d = h.shape
    nh, dh = cfg.n_heads, cfg.d_head
    qkv = _mm(cfg, h.reshape(b * t, d), p[f"layer{i}.qkv"]).reshape(b, t, 3, nh, dh)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    # (b, nh, t, dh)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bhsd->bhtd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b * t, d)
    return _mm(cfg, ctx, p[f"layer{i}.attn_out"]).reshape(b, t, d)


def _mlp(cfg: ModelConfig, p: Dict[str, jnp.ndarray], i: int, h: jnp.ndarray) -> jnp.ndarray:
    b, t, d = h.shape
    x = _mm(cfg, h.reshape(b * t, d), p[f"layer{i}.mlp_in"])
    x = jax.nn.gelu(x)
    return _mm(cfg, x, p[f"layer{i}.mlp_out"]).reshape(b, t, d)


def forward(cfg: ModelConfig, flat: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Logits (batch, seq, vocab). Output embedding is tied to the input
    embedding (Press & Wolf, the paper's LSTM setup does the same)."""
    p = unflatten(cfg, flat)
    b, t = tokens.shape
    h = p["embed"][tokens] + p["pos"][None, :t]
    for i in range(cfg.n_layers):
        h = h + _attention(cfg, p, i, _layernorm(h, p[f"layer{i}.ln1_scale"], p[f"layer{i}.ln1_bias"]))
        h = h + _mlp(cfg, p, i, _layernorm(h, p[f"layer{i}.ln2_scale"], p[f"layer{i}.ln2_bias"]))
    h = _layernorm(h, p["ln_f_scale"], p["ln_f_bias"])
    logits = _mm(cfg, h.reshape(b * t, cfg.d_model), p["embed"].T)
    return logits.reshape(b, t, cfg.vocab)


def loss_fn(cfg: ModelConfig, flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross entropy (nats)."""
    logits = forward(cfg, flat, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def train_step(cfg: ModelConfig, flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, lr: jnp.ndarray):
    """One local SGD step: returns (new_flat, loss)."""
    loss, grad = jax.value_and_grad(lambda f: loss_fn(cfg, f, x, y))(flat)
    return flat - lr * grad, loss


def eval_step(cfg: ModelConfig, flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    return loss_fn(cfg, flat, x, y)


def mix_step(cfg: ModelConfig, w: jnp.ndarray, stacked: jnp.ndarray) -> jnp.ndarray:
    """Consensus: stacked' = W @ stacked via the Pallas mix kernel."""
    if cfg.use_pallas:
        return pallas_mix(w, stacked)
    return jnp.dot(w, stacked, preferred_element_type=jnp.float32)
