"""AOT compilation: lower the L2 model to HLO *text* artifacts.

Run once by ``make artifacts``; Python never appears on the training
path. Interchange format is HLO text, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Artifacts (for a preset P and worker count m):
  artifacts/train_step.hlo.txt        pallas-kernel path
  artifacts/train_step_fused.hlo.txt  jnp.dot path (CPU fast path)
  artifacts/eval_step.hlo.txt
  artifacts/mix.hlo.txt               pallas gossip kernel, (m, d)
  artifacts/mix_fused.hlo.txt         jnp.dot gossip (CPU fast path)
  artifacts/meta.json                 config + flat-parameter layout

Usage: python -m compile.aot --out-dir ../artifacts --preset small --workers 8
"""

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple so the Rust
    side unwraps a single tuple output)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg: M.ModelConfig) -> str:
    d = M.param_count(cfg)
    flat = jax.ShapeDtypeStruct((d,), jnp.float32)
    toks = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    def step(flat, x, y, lr):
        new, loss = M.train_step(cfg, flat, x, y, lr)
        return (new, loss)

    return to_hlo_text(jax.jit(step, donate_argnums=(0,)).lower(flat, toks, toks, lr))


def lower_eval_step(cfg: M.ModelConfig) -> str:
    d = M.param_count(cfg)
    flat = jax.ShapeDtypeStruct((d,), jnp.float32)
    toks = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)

    def step(flat, x, y):
        return (M.eval_step(cfg, flat, x, y),)

    return to_hlo_text(jax.jit(step).lower(flat, toks, toks))


def lower_mix(cfg: M.ModelConfig, workers: int) -> str:
    d = M.param_count(cfg)
    w = jax.ShapeDtypeStruct((workers, workers), jnp.float32)
    stacked = jax.ShapeDtypeStruct((workers, d), jnp.float32)

    def step(w, stacked):
        return (M.mix_step(cfg, w, stacked),)

    return to_hlo_text(jax.jit(step).lower(w, stacked))


def build_meta(cfg: M.ModelConfig, workers: int) -> dict:
    return {
        "preset": getattr(cfg, "_preset_name", "custom"),
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "workers": workers,
        "param_count": M.param_count(cfg),
        "params": [
            {
                "name": e.name,
                "shape": list(e.shape),
                "offset": e.offset,
                "size": e.size,
                "init": e.init,
                "std": e.std,
            }
            for e in M.param_spec(cfg)
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(M.PRESETS))
    ap.add_argument("--workers", type=int, default=8)
    args = ap.parse_args()

    cfg = M.PRESETS[args.preset]
    object.__setattr__(cfg, "_preset_name", args.preset)
    cfg_fused = dataclasses.replace(cfg, use_pallas=False)
    os.makedirs(args.out_dir, exist_ok=True)

    outputs = {
        "train_step.hlo.txt": lambda: lower_train_step(cfg),
        "train_step_fused.hlo.txt": lambda: lower_train_step(cfg_fused),
        "eval_step.hlo.txt": lambda: lower_eval_step(cfg_fused),
        "mix.hlo.txt": lambda: lower_mix(cfg, args.workers),
        "mix_fused.hlo.txt": lambda: lower_mix(cfg_fused, args.workers),
    }
    for name, build in outputs.items():
        text = build()
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = build_meta(cfg, args.workers)
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path} (param_count={meta['param_count']})")


if __name__ == "__main__":
    main()
