"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with nothing but `jnp` primitives. `python/tests/` asserts
allclose between kernel and oracle across shape/dtype sweeps — this is
the L1 correctness signal of the build.
"""

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Plain matrix product with f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def mix_ref(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Gossip consensus step: X' = W @ X.

    ``w`` is the m-by-m mixing matrix W = I - alpha * sum_j B_j L_j;
    ``x`` stacks the m workers' flat parameter vectors row-wise.
    """
    return jnp.matmul(w, x, preferred_element_type=jnp.float32)
