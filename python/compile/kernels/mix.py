"""Gossip-mixing Pallas kernel: X' = W @ X.

The consensus step of decentralized SGD stacks the m workers' flat
parameter vectors into X (m-by-d) and multiplies by the iteration's
mixing matrix W (m-by-m, symmetric doubly stochastic). m is small (8–64)
but d is the full parameter count, so the kernel keeps W resident and
tiles X along the parameter axis: grid = (d / BLOCK_D,), each step loads
an (m, BLOCK_D) slab of X into VMEM, multiplies by W, and writes the slab
back. This is a pure VMEM-bandwidth kernel (the paper's communication hot
spot, as opposed to the matmul compute hot spot).

Runs with ``interpret=True`` for the CPU PJRT client (see matmul.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Parameter-axis tile. 4096 f32 columns x m<=64 rows = <=1 MiB per slab,
# comfortably within a TPU core's ~16 MiB VMEM alongside W, and large
# enough that grid overhead is negligible (interpret mode pays per grid
# step; see EXPERIMENTS.md §Perf).
BLOCK_D = 4096


def _mix_kernel(w_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(
        w_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def mix(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Consensus step ``w @ x`` with W resident and X tiled along d."""
    assert w.ndim == 2 and w.shape[0] == w.shape[1], w.shape
    assert x.ndim == 2 and x.shape[0] == w.shape[0], (w.shape, x.shape)
    m, d = x.shape
    bd = min(BLOCK_D, d)
    dp = (d + bd - 1) // bd * bd
    xp = jnp.pad(x, ((0, 0), (0, dp - d)))

    out = pl.pallas_call(
        _mix_kernel,
        grid=(dp // bd,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),  # W resident
            pl.BlockSpec((m, bd), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, dp), jnp.float32),
        interpret=True,
    )(w, xp)
    return out[:, :d].astype(x.dtype)


def vmem_footprint_bytes(m: int, d: int) -> int:
    """Estimated VMEM working set per grid step (for §Perf reporting)."""
    bd = min(BLOCK_D, d)
    return m * m * 4 + 2 * m * bd * 4
