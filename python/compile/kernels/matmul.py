"""Tiled matmul Pallas kernel with a custom VJP.

This is the compute hot spot of decentralized SGD: every projection and
feed-forward layer in the L2 transformer routes its (rows, in) @ (in, out)
product through this kernel, forward and backward.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows/cols to
128x128 MXU-shaped blocks held in VMEM, with an f32 accumulator updated
across the K grid dimension (K is the innermost, sequential grid axis, so
the output block stays resident in VMEM between K steps — the standard
Pallas accumulation idiom). On this CPU-only image the kernel always runs
with ``interpret=True``: real TPU lowering emits a Mosaic custom-call the
CPU PJRT client cannot execute. The BlockSpec structure — and therefore
the VMEM footprint / MXU utilization estimates in EXPERIMENTS.md §Perf —
is the same either way.

Pallas kernels have no automatic differentiation rule, so ``matmul`` is
wrapped in ``jax.custom_vjp`` whose backward pass reuses the same kernel:
dX = dZ @ Yᵀ and dY = Xᵀ @ dZ.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic array edge; tiles clamp
# to the (padded) problem size for small operands.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: O[i,j] += X[i,k] @ Y[k,j].

    K is the innermost (sequential) grid axis, so the output block stays
    resident between K steps and serves as the f32 accumulator — the
    standard Pallas accumulation idiom (all model tensors are f32, so
    accumulating in ``o_ref`` loses no precision vs a scratch buffer).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(v: int, b: int) -> int:
    return (v + b - 1) // b * b


def _pallas_matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Raw (non-differentiable) tiled Pallas matmul, any 2-D shapes."""
    assert x.ndim == 2 and y.ndim == 2 and x.shape[1] == y.shape[0], (
        x.shape,
        y.shape,
    )
    m, k = x.shape
    _, n = y.shape
    bm, bn, bk = min(BLOCK_M, m), min(BLOCK_N, n), min(BLOCK_K, k)

    # Pad every dimension up to a tile multiple; slice the result back.
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    k_tiles = kp // bk

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, k_tiles),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n].astype(x.dtype)


@jax.custom_vjp
def matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Differentiable tiled-Pallas matrix product ``x @ y``."""
    return _pallas_matmul(x, y)


def _matmul_fwd(x, y):
    return _pallas_matmul(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dX = g @ Yᵀ, dY = Xᵀ @ g — both through the same Pallas kernel.
    return _pallas_matmul(g, y.T), _pallas_matmul(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(m: int, n: int, k: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set per grid step (for §Perf reporting):
    one X tile + one Y tile + the f32 accumulator + the output tile."""
    bm, bn, bk = min(BLOCK_M, m), min(BLOCK_N, n), min(BLOCK_K, k)
    return bm * bk * dtype_bytes + bk * bn * dtype_bytes + bm * bn * (4 + dtype_bytes)
