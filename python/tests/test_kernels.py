"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including awkward non-tile-multiple sizes) and
seeds; assert_allclose against ref.py is the build's core kernel signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul, vmem_footprint_bytes
from compile.kernels.mix import mix, vmem_footprint_bytes as mix_vmem
from compile.kernels.ref import matmul_ref, mix_ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _rand(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), dtype)


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand((m, k), seed)
    y = _rand((k, n), seed + 1)
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "shape",
    [
        (128, 128, 128),  # exactly one tile
        (256, 128, 384),  # multi-tile every axis
        (129, 127, 130),  # off-by-one around tile edges
        (1, 1, 1),
        (1024, 64, 64),   # tall-skinny (the B*T x d shape the model uses)
    ],
)
def test_matmul_tile_boundaries(shape):
    m, k, n = shape
    x = _rand((m, k), 7)
    y = _rand((k, n), 8)
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y), rtol=2e-5, atol=2e-5)


@given(
    m=st.integers(2, 40),
    k=st.integers(2, 40),
    n=st.integers(2, 40),
    seed=st.integers(0, 2**16),
)
def test_matmul_vjp_matches_ref(m, k, n, seed):
    x = _rand((m, k), seed)
    y = _rand((k, n), seed + 1)
    ct = _rand((m, n), seed + 2)

    def f_kernel(a, b):
        return jnp.vdot(matmul(a, b), ct)

    def f_ref(a, b):
        return jnp.vdot(matmul_ref(a, b), ct)

    gk = jax.grad(f_kernel, argnums=(0, 1))(x, y)
    gr = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gk[0], gr[0], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(gk[1], gr[1], rtol=2e-4, atol=2e-4)


def test_matmul_jittable_and_grad_through_jit():
    x = _rand((33, 20), 1)
    y = _rand((20, 17), 2)
    f = jax.jit(lambda a, b: jnp.sum(matmul(a, b) ** 2))
    g = jax.grad(f)(x, y)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))


@given(
    m=st.integers(2, 32),
    d=st.integers(1, 2000),
    seed=st.integers(0, 2**16),
)
def test_mix_matches_ref(m, d, seed):
    w = _rand((m, m), seed)
    x = _rand((m, d), seed + 1)
    np.testing.assert_allclose(mix(w, x), mix_ref(w, x), rtol=2e-5, atol=2e-5)


def test_mix_preserves_average_for_doubly_stochastic_w():
    # Column sums of a doubly stochastic W are 1, so the worker-average
    # parameter vector is invariant under the consensus step (the
    # algebraic fact the paper's x-bar analysis relies on).
    m, d = 8, 513
    rng = np.random.RandomState(0)
    # W = I - alpha L for a ring laplacian: doubly stochastic.
    L = np.zeros((m, m), np.float32)
    for i in range(m):
        L[i, i] = 2
        L[i, (i + 1) % m] -= 1
        L[i, (i - 1) % m] -= 1
    w = jnp.asarray(np.eye(m, dtype=np.float32) - 0.3 * L)
    x = jnp.asarray(rng.randn(m, d), jnp.float32)
    mixed = mix(w, x)
    np.testing.assert_allclose(
        jnp.mean(mixed, axis=0), jnp.mean(x, axis=0), rtol=1e-5, atol=1e-5
    )


def test_mix_identity_w_is_noop():
    x = _rand((4, 300), 3)
    out = mix(jnp.eye(4, dtype=jnp.float32), x)
    np.testing.assert_allclose(out, x, rtol=1e-6, atol=1e-6)


def test_vmem_footprints_within_tpu_budget():
    # Sanity for the §Perf estimates: working sets must be well under a
    # TPU core's ~16 MiB VMEM.
    assert vmem_footprint_bytes(1024, 384, 128) < 16 * 2**20
    assert mix_vmem(64, 3_200_000) < 16 * 2**20
