"""L2 correctness: parameter layout, forward/loss invariants, training
signal, and pallas-vs-fused path parity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.PRESETS["tiny"]
TINY_FUSED = dataclasses.replace(TINY, use_pallas=False)


def _data(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(k)
    x = jax.random.randint(kx, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    y = jax.random.randint(ky, (cfg.batch, cfg.seq_len), 0, cfg.vocab)
    return x, y


def test_param_spec_is_contiguous_and_matches_count():
    for cfg in M.PRESETS.values():
        spec = M.param_spec(cfg)
        offset = 0
        names = set()
        for e in spec:
            assert e.offset == offset, f"{e.name}: gap in layout"
            assert e.name not in names, f"duplicate {e.name}"
            names.add(e.name)
            offset += e.size
        assert offset == M.param_count(cfg)


def test_unflatten_shapes():
    flat = M.init_params(TINY, jax.random.PRNGKey(0))
    p = M.unflatten(TINY, flat)
    assert p["embed"].shape == (TINY.vocab, TINY.d_model)
    assert p["layer0.qkv"].shape == (TINY.d_model, 3 * TINY.d_model)
    assert p["ln_f_scale"].shape == (TINY.d_model,)
    np.testing.assert_allclose(p["ln_f_scale"], 1.0)
    np.testing.assert_allclose(p["ln_f_bias"], 0.0)


def test_initial_loss_near_uniform_entropy():
    flat = M.init_params(TINY_FUSED, jax.random.PRNGKey(1))
    x, y = _data(TINY_FUSED)
    loss = float(M.loss_fn(TINY_FUSED, flat, x, y))
    uniform = float(np.log(TINY.vocab))
    # Tied in/out embeddings give the init logits some variance, so allow
    # a generous band around ln V — the point is "sane init", not exact
    # uniformity.
    assert uniform - 0.5 < loss < uniform + 1.0, f"init loss {loss} vs ln V {uniform}"


def test_causality_future_tokens_do_not_affect_logits():
    flat = M.init_params(TINY_FUSED, jax.random.PRNGKey(2))
    x, _ = _data(TINY_FUSED)
    logits = M.forward(TINY_FUSED, flat, x)
    # Perturb the last token; logits at all earlier positions unchanged.
    x2 = x.at[:, -1].set((x[:, -1] + 1) % TINY.vocab)
    logits2 = M.forward(TINY_FUSED, flat, x2)
    np.testing.assert_allclose(logits[:, :-1], logits2[:, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(logits[:, -1], logits2[:, -1])


def test_train_step_reduces_loss_on_fixed_batch():
    flat = M.init_params(TINY_FUSED, jax.random.PRNGKey(3))
    x, y = _data(TINY_FUSED, seed=3)
    step = jax.jit(lambda f: M.train_step(TINY_FUSED, f, x, y, jnp.float32(0.5)))
    losses = []
    for _ in range(8):
        flat, loss = step(flat)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, f"no learning: {losses}"
    assert np.all(np.isfinite(losses))


def test_pallas_and_fused_paths_agree():
    flat = M.init_params(TINY, jax.random.PRNGKey(4))
    x, y = _data(TINY, seed=4)
    lp = float(M.loss_fn(TINY, flat, x, y))
    lf = float(M.loss_fn(TINY_FUSED, flat, x, y))
    assert abs(lp - lf) < 1e-4, f"pallas {lp} vs fused {lf}"
    # One gradient step must match too (kernels used in bwd as well).
    np_, lossp = M.train_step(TINY, flat, x, y, jnp.float32(0.1))
    nf, lossf = M.train_step(TINY_FUSED, flat, x, y, jnp.float32(0.1))
    assert abs(float(lossp) - float(lossf)) < 1e-4
    np.testing.assert_allclose(np_, nf, rtol=5e-4, atol=5e-4)


def test_eval_step_matches_loss_fn():
    flat = M.init_params(TINY_FUSED, jax.random.PRNGKey(5))
    x, y = _data(TINY_FUSED, seed=5)
    a = float(M.eval_step(TINY_FUSED, flat, x, y))
    b = float(M.loss_fn(TINY_FUSED, flat, x, y))
    assert a == pytest.approx(b)


def test_mix_step_preserves_mean_and_converges_to_consensus():
    m = 8
    d = M.param_count(TINY)
    rng = np.random.RandomState(7)
    stacked = jnp.asarray(rng.randn(m, d) * 0.1, jnp.float32)
    # Ring mixing matrix, alpha=0.3: doubly stochastic with rho < 1.
    L = np.zeros((m, m), np.float32)
    for i in range(m):
        L[i, i] = 2
        L[i, (i + 1) % m] -= 1
        L[i, (i - 1) % m] -= 1
    w = jnp.asarray(np.eye(m, dtype=np.float32) - 0.3 * L)
    mean0 = jnp.mean(stacked, axis=0)
    x = stacked
    spread = []
    for _ in range(30):
        x = M.mix_step(TINY, w, x)
        spread.append(float(jnp.mean(jnp.square(x - jnp.mean(x, axis=0)))))
    np.testing.assert_allclose(jnp.mean(x, axis=0), mean0, rtol=1e-4, atol=1e-5)
    assert spread[-1] < 1e-3 * spread[0], f"no consensus: {spread[0]} -> {spread[-1]}"


def test_forward_handles_all_token_values():
    flat = M.init_params(TINY_FUSED, jax.random.PRNGKey(8))
    x = jnp.full((TINY.batch, TINY.seq_len), TINY.vocab - 1, jnp.int32)
    logits = M.forward(TINY_FUSED, flat, x)
    assert bool(jnp.all(jnp.isfinite(logits)))
