"""AOT path: lowering produces loadable HLO text and a consistent
meta.json contract for the Rust side."""

import json

import pytest

from compile import aot
from compile import model as M

TINY = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def tiny_hlo():
    # Lower once for the module (lowering is the slow part).
    return {
        "train": aot.lower_train_step(TINY),
        "eval": aot.lower_eval_step(TINY),
        "mix": aot.lower_mix(TINY, workers=4),
    }


def test_hlo_text_shape(tiny_hlo):
    for name, text in tiny_hlo.items():
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: no entry computation"


def test_train_step_signature(tiny_hlo):
    d = M.param_count(TINY)
    text = tiny_hlo["train"]
    # Parameters: flat f32[d], two int32[batch, seq] token arrays, f32[] lr.
    assert f"f32[{d}]" in text
    assert f"s32[{TINY.batch},{TINY.seq_len}]" in text
    # Output is a tuple (new_params, loss).
    assert f"(f32[{d}]" in text


def test_mix_signature(tiny_hlo):
    d = M.param_count(TINY)
    text = tiny_hlo["mix"]
    assert f"f32[4,{d}]" in text
    assert "f32[4,4]" in text


def test_meta_contract():
    meta = aot.build_meta(TINY, workers=4)
    # Round-trip through JSON (what the Rust parser consumes).
    meta = json.loads(json.dumps(meta))
    assert meta["param_count"] == M.param_count(TINY)
    assert meta["workers"] == 4
    spec = meta["params"]
    # Contiguity and size consistency.
    offset = 0
    for e in spec:
        assert e["offset"] == offset
        size = 1
        for s in e["shape"]:
            size *= s
        assert e["size"] == size
        assert e["init"] in ("normal", "ones", "zeros")
        offset += size
    assert offset == meta["param_count"]


def test_meta_vocab_matches_rust_corpus():
    # rust/src/data/mod.rs hardcodes VOCAB=64; the model must agree.
    assert TINY.vocab == 64
